"""Communication-volume accounting (the paper's 'Com. red.' column).

Analytic bytes-per-outer-step for every algorithm, cross-checked against
the dry-run's HLO collective parse for DSM (benchmarks/run.py prints both).

``phase_collective_budget`` turns the same model into the machine-checked
per-phase budgets consumed by the static auditor
(``repro.analysis.hlo_audit``): the analytic round counts here are LOGICAL
rounds; the auditor multiplies them out to per-leaf HLO op ceilings and
payload-byte ceilings and checks the compiled program against them.
"""

from __future__ import annotations

from repro.configs import load_arch
from repro.configs import specs as S


GLOBAL_STATE_BYTES = 4      # x0 and m are f32 by default
GLOBAL_STEP_PASSES = 5      # HBM traffic of eqs. 6-8: read x0, m, x_tau; write x0, m


LOCAL_STEP_ALGOS = ("dsm", "slowmo", "signed_slowmo", "lookahead",
                    "global_adamw", "local_avg")


def wire_bytes_for_payload(payload_bytes: int, algo: str, tau: int,
                           param_bytes: int = 2) -> tuple:
    """``(wire_bytes_per_outer, comm_rounds_per_outer)`` for a raw payload.

    The round model shared by ``bytes_per_outer_step`` (which derives the
    payload from an arch id) and the runtime comm ledger
    (``repro.obs.ledger``, which derives it from the live param pytree):
    one all-reduce ~ 2x payload on the ring, per logical round.
    """
    if algo in LOCAL_STEP_ALGOS:
        return 2 * payload_bytes, 1        # one model all-reduce / outer step
    if algo == "perstep":
        return 2 * payload_bytes * tau, tau  # gradient all-reduce every step
    if algo == "mv_signsgd":
        return payload_bytes // (8 * param_bytes) * 2, 1  # 1-bit signs each way
    raise ValueError(algo)


def bytes_per_outer_step(arch_id: str, algo: str, tau: int,
                         param_bytes: int = 2, zero_sharded: bool = False,
                         shards: int = 1, device_parallel: bool = False,
                         n_workers: int = 8,
                         survivor_frac: float = 1.0) -> dict:
    """Inter-worker (slow-network) bytes per tau local steps, per the
    all-reduce ~ 2x payload ring model.  Intra-worker TP traffic excluded
    (that is the fast-network budget).

    ``zero_sharded`` / ``shards``: DSM's ZeRO-sharded global step over
    R = worker * zero ranks.  Wire bytes are unchanged (reduce-scatter +
    all-gather ~ one all-reduce), but each rank now holds and updates only
    1/R of the global x0 / m buffers — the per-rank HBM figures below are
    what the sharding buys.

    ``device_parallel`` / ``n_workers``: the local phase's execution layout.
    The vmapped simulation replicates all ``n_workers`` workers' tau local
    steps onto every rank; the shard_mapped layout runs exactly one worker's
    share per rank (wire bytes unchanged — the local phase is collective-free
    either way).  ``local_step_flops_replication`` is the per-rank local
    compute multiplier the layout implies.

    ``survivor_frac``: expected fraction of worker contributions that arrive
    each round under dropout (``1 - FaultPlan.dropped_frac()``).  A dropped
    worker sources nothing into the round's reduction, so the *expected*
    fabric traffic scales with the survivor fraction while the per-survivor
    bytes (and round count — the all-reduce still happens) do not.
    """
    if not 0.0 <= survivor_frac <= 1.0:
        raise ValueError(f"survivor_frac={survivor_frac} must lie in [0, 1]")
    cfg = load_arch(arch_id).FULL
    n = S.param_count(cfg)
    payload = n * param_bytes
    wire, rounds = wire_bytes_for_payload(payload, algo, tau,
                                          param_bytes=param_bytes)
    out = {
        "arch": arch_id, "algo": algo, "tau": tau,
        "wire_bytes_per_outer": wire,
        "comm_rounds_per_outer": rounds,
        "reduction_vs_perstep": (2 * payload * tau) / max(wire, 1),
        "survivor_frac": survivor_frac,
        "expected_wire_bytes_per_outer": int(round(wire * survivor_frac)),
    }
    if algo in LOCAL_STEP_ALGOS:
        out["local_phase_device_parallel"] = device_parallel
        out["local_step_flops_replication"] = 1 if device_parallel else n_workers
    if algo == "dsm":
        r = shards if zero_sharded else 1
        out["zero_sharded"] = zero_sharded
        out["global_state_shards"] = r
        # per-rank residency of the global buffers (x0 + m) ...
        out["global_state_bytes_per_rank"] = 2 * n * GLOBAL_STATE_BYTES // r
        # ... and per-rank HBM traffic of the global update itself
        out["global_buffer_bytes_per_rank"] = (
            GLOBAL_STEP_PASSES * n * GLOBAL_STATE_BYTES // r
        )
        # bytes each rank sources into the x_{t+1,0} all-gather (replicated
        # ranks all recompute the full update; sharded ranks own 1/R of it)
        out["broadcast_src_bytes_per_rank"] = payload // r
    return out


# ---------------------------------------------------------------------------
# Collective budgets for the static auditor (repro.analysis.hlo_audit)
# ---------------------------------------------------------------------------

# A logical worker reduction may lower as `reduce-scatter` (collective-capable
# backends) or as `all-reduce` + local slice (the CPU partitioner's choice for
# the GSPMD scattered mean — see docs/sharding.md); both implement the same
# single round of the ring model above, so the budget treats them as one
# equivalence class.  Ops outside the declared classes (all-to-all,
# collective-permute, ...) are never part of Algorithm 1's outer step and any
# occurrence is a budget violation.
REDUCE_CLASS = ("all-reduce", "reduce-scatter")
GATHER_CLASS = ("all-gather",)

PHASES = ("local", "global_dense", "global_zero")


def phase_collective_budget(phase: str, *, n_param_leaves: int,
                            payload_bytes: int,
                            n_metric_reductions: int = 2,
                            payload_slack: float = 1.5) -> dict:
    """LOGICAL per-phase budget, derived from the round model above.

    ``bytes_per_outer_step`` counts one model-payload reduction round per
    outer step for every local-step algorithm (``comm_rounds_per_outer=1``)
    and none inside the tau local steps — the paper's communication claim.
    XLA lowers a logical round leafwise, so the op ceilings multiply the
    round count by ``n_param_leaves`` (+ ``n_metric_reductions`` scalar
    reductions for the loss metrics, which ride along with the global step);
    the payload ceilings multiply the model payload by ``payload_slack``
    (dtype/padding headroom — metric scalars are absorbed by a 1 KiB floor).

      * ``local``        — the tau local steps: ZERO collectives of any kind.
      * ``global_dense`` — replicated global step: one reduction round
        (the paper's single all-reduce), nothing else.
      * ``global_zero``  — ZeRO-sharded global step: one reduction round
        (reduce-scatter, or all-reduce on backends without it) plus one
        gather round (the x_{t+1,0} broadcast / all-gather); no stray
        second reduction.
    """
    if phase not in PHASES:
        raise ValueError(f"phase must be one of {PHASES}, got {phase!r}")
    reduce_rounds = 0 if phase == "local" else 1
    gather_rounds = 1 if phase == "global_zero" else 0
    pay = int(payload_slack * payload_bytes) + 1024
    return {
        "phase": phase,
        "reduce_rounds": reduce_rounds,
        "gather_rounds": gather_rounds,
        "max_reduce_ops": reduce_rounds * (n_param_leaves + n_metric_reductions),
        "max_gather_ops": gather_rounds * (n_param_leaves + n_metric_reductions),
        "max_reduce_bytes": reduce_rounds * pay,
        "max_gather_bytes": gather_rounds * pay,
        "reduce_class": list(REDUCE_CLASS),
        "gather_class": list(GATHER_CLASS),
    }
