"""Communication-volume accounting (the paper's 'Com. red.' column).

Analytic bytes-per-outer-step for every algorithm, cross-checked against
the dry-run's HLO collective parse for DSM (benchmarks/run.py prints both).
"""

from __future__ import annotations

from repro.configs import load_arch
from repro.configs import specs as S


def bytes_per_outer_step(arch_id: str, algo: str, tau: int,
                         param_bytes: int = 2) -> dict:
    """Inter-worker (slow-network) bytes per tau local steps, per the
    all-reduce ~ 2x payload ring model.  Intra-worker TP traffic excluded
    (that is the fast-network budget)."""
    cfg = load_arch(arch_id).FULL
    n = S.param_count(cfg)
    payload = n * param_bytes
    if algo in ("dsm", "slowmo", "signed_slowmo", "lookahead", "global_adamw",
                "local_avg"):
        wire = 2 * payload                      # one model all-reduce / outer step
        rounds = 1
    elif algo == "perstep":
        wire = 2 * payload * tau                # gradient all-reduce every step
        rounds = tau
    elif algo == "mv_signsgd":
        wire = payload // (8 * param_bytes) * 2  # 1-bit signs each way
        rounds = 1
    else:
        raise ValueError(algo)
    return {
        "arch": arch_id, "algo": algo, "tau": tau,
        "wire_bytes_per_outer": wire,
        "comm_rounds_per_outer": rounds,
        "reduction_vs_perstep": (2 * payload * tau) / max(wire, 1),
    }
