"""SPerf hillclimbs: hypothesis -> change -> re-lower -> validate, on the
three chosen (arch x shape) pairs. Each entry prints before/after roofline
terms and appends a JSON record to experiments/perf/.

Pairs (chosen per EXPERIMENTS.md SRoofline):
  1. minitron_4b x train_4k      — worst roofline fraction (collective-bound,
                                    hd-split attention pathology at kv=8,TP=16)
  2. granite_moe x train_4k      — most collective-bound (TK-row all-reduce)
  3. deepseek_67b x train_4k     — most representative of the paper's
                                    technique (largest global-step payload)

Run after the baseline roofline pass:
  PYTHONPATH=src python -m benchmarks.hillclimb --pair all
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json

from benchmarks import roofline as R


def _emit(tag, rec):
    os.makedirs("experiments/perf", exist_ok=True)
    with open(f"experiments/perf/{tag}.json", "w") as f:
        json.dump(rec, f, indent=1)
    if rec.get("status") == "ok" or "t_compute_s" in rec:
        print(f"{tag}: tc={rec['t_compute_s']:.3e} tm={rec['t_memory_s']:.3e} "
              f"tn={rec['t_collective_s']:.3e} dom={rec['dominant']} "
              f"useful={rec['useful_flops_ratio']:.2f}", flush=True)
    else:
        print(f"{tag}: ERROR {rec.get('error')}", flush=True)


def pair_minitron():
    """Hypothesis: with kv_heads(8) < model(16), sharding wk/wv on kvh*hd
    splits head_dim -> SPMD emits 'involuntary full rematerialization'
    reshards + f32 partial-score all-reduces.  Replicating attention weights
    over the model axis (attn params = 26% of layer) trades ~16x redundant
    attention FLOPs (attention is <15% of layer FLOPs at seq 4k) for the
    removal of every attention-side collective. Predicted: tn drops >5x,
    tc grows <1.2x."""
    base = R.roofline_train("minitron_4b", "train_4k", False)
    _emit("minitron_attn_tp_baseline", base)
    opt = R.roofline_train("minitron_4b", "train_4k", False,
                           overrides=dict(attn_tp=False))
    _emit("minitron_attn_replicated", opt)
    return base, opt


def pair_moe():
    """Hypothesis: the row-parallel MoE all-reduce happens at TK = top_k*T
    rows (scatter-add forces materialization before combine). Contracting
    the K assignments with the gates BEFORE the reduce (moe_combine='ksum')
    shrinks the reduced tensor 8x (top-8). Predicted: micro wire ~/8 on the
    MoE share of traffic."""
    base = R.roofline_train("granite_moe_3b_a800m", "train_4k", False)
    _emit("moe_scatter_baseline", base)
    opt = R.roofline_train("granite_moe_3b_a800m", "train_4k", False,
                           cfg_overrides=dict(moe_combine="ksum"))
    _emit("moe_ksum", opt)
    return base, opt


def pair_deepseek():
    """Paper-representative pair: the tau-amortized global step moves the
    largest payload (134 GB model). Hypothesis: sharding the global buffers
    over the worker axis (the paper's own 'distribute global buffers across
    nodes') turns all-reduce(x_tau) + re-broadcast(x_new) [~3x payload] into
    reduce-scatter + all-gather [2x payload] and divides the sign-step
    HBM traffic by n. Predicted: global-step wire x2/3, global bytes /W."""
    base = R.roofline_train("deepseek_67b", "train_4k", False,
                            zero_global_buffers=False)
    _emit("deepseek_global_baseline", base)
    opt = R.roofline_train("deepseek_67b", "train_4k", False,
                           zero_global_buffers=True)
    _emit("deepseek_global_zero_sharded", opt)
    return base, opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default="all",
                    choices=("all", "minitron", "moe", "deepseek"))
    args = ap.parse_args()
    if args.pair in ("all", "minitron"):
        pair_minitron()
    if args.pair in ("all", "moe"):
        pair_moe()
    if args.pair in ("all", "deepseek"):
        pair_deepseek()


if __name__ == "__main__":
    main()
