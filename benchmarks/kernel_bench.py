"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-clock there is meaningless; we therefore report:
  * us_per_call of the jnp (XLA-fused) reference path on CPU, and
  * the DERIVED TPU roofline time: HBM-bound bytes / 819 GB/s — the number
    the fused kernel is built to achieve (3 reads + 2 writes for DSM;
    4 reads + 3 writes for AdamW).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref

HBM_BW = 819e9


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def bench_dsm_kernel(n=1_000_000):
    k_x0, k_m = jax.random.split(jax.random.PRNGKey(0))
    x0 = jax.random.normal(k_x0, (n,), jnp.float32).astype(jnp.bfloat16)
    m = jax.random.normal(k_m, (n,), jnp.float32)
    xt = (x0.astype(jnp.float32) - 0.01).astype(jnp.bfloat16)
    gamma = jnp.float32(0.01)
    hp = dict(eta=1.0, beta1=0.95, beta2=0.98, lam=0.1)

    jitted = jax.jit(lambda a, b, c: ref.dsm_update_ref(a, b, c, gamma, **hp))
    us = _time(jitted, x0, m, xt)
    # bytes: read x0(2) + m(4) + xt(2), write x(2) + m(4) per element
    bytes_total = n * (2 + 4 + 2 + 2 + 4)
    derived_tpu_us = bytes_total / HBM_BW * 1e6
    return ("dsm_update_1M", us, f"tpu_roofline_us={derived_tpu_us:.1f}")


def bench_adamw_kernel(n=1_000_000):
    k_p, k_g, k_m, k_v = jax.random.split(jax.random.PRNGKey(1), 4)
    p = jax.random.normal(k_p, (n,), jnp.float32).astype(jnp.bfloat16)
    g = jax.random.normal(k_g, (n,), jnp.float32).astype(jnp.bfloat16)
    m = jax.random.normal(k_m, (n,), jnp.float32)
    v = jnp.abs(jax.random.normal(k_v, (n,), jnp.float32))
    gamma, step = jnp.float32(1e-3), jnp.float32(3)

    jitted = jax.jit(lambda a, b, c, d: ref.adamw_update_ref(
        a, b, c, d, gamma, step, beta1=0.9, beta2=0.95, eps=1e-8, wd=0.1))
    us = _time(jitted, p, g, m, v)
    bytes_total = n * (2 + 2 + 4 + 4 + 2 + 4 + 4)
    derived_tpu_us = bytes_total / HBM_BW * 1e6
    return ("adamw_update_1M", us, f"tpu_roofline_us={derived_tpu_us:.1f}")


def bench_interpret_correct(n=100_000):
    """Pallas interpret path (correctness-representative, not perf)."""
    from repro.kernels import ops

    key = jax.random.PRNGKey(2)
    x0 = jax.random.normal(key, (n,), jnp.float32)
    m = jnp.zeros((n,), jnp.float32)
    xt = x0 - 0.01
    t0 = time.perf_counter()
    ops.dsm_update_tree({"a": x0}, {"a": m}, {"a": xt}, jnp.float32(0.01),
                        eta=1.0, beta1=0.95, beta2=0.98, lam=0.1)
    us = (time.perf_counter() - t0) * 1e6
    return ("dsm_pallas_interpret_100k", us, "correctness_mode")
