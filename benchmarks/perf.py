"""Perf snapshot: the bench-trajectory artifact (``BENCH_<name>.json``).

Runs a short instrumented nano-DSM training through the real trainer with
an obs run directory, and distills it into one JSON snapshot at the repo
root: steps/sec, tokens/sec, and per-phase milliseconds from the obs spans
(train window, local-phase / global-step probe, eval, checkpoint).  CI's
nightly job regenerates it so the trajectory of the numbers is visible in
version control — ROADMAP's "fast as the hardware allows" needs a baseline
to beat.

Snapshots are environment-dependent (CPU count, jax version); the manifest
fields embedded in the snapshot say where a number came from.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Optional


def perf_snapshot(steps: int = 12, n_workers: int = 4, tau: int = 4,
                  run_dir: Optional[str] = None) -> dict:
    """Train nano-DSM with obs enabled; return the snapshot dict."""
    import jax

    from benchmarks.tables import NANO
    from repro.data.pipeline import MarkovCorpus
    from repro.obs.summarize import summarize_run
    from repro.train.trainer import TrainSettings, run_training

    owns_dir = run_dir is None
    if owns_dir:
        run_dir = tempfile.mkdtemp(prefix="perf_snapshot_")
    s = TrainSettings(
        algorithm="dsm", n_workers=n_workers, tau=tau, steps=steps,
        eval_every=max(steps // 2, 1), run_dir=run_dir,
    )
    result = run_training(NANO, s, MarkovCorpus(NANO.vocab_size, seed=1))
    summary = summarize_run(run_dir)

    wall = result["wall_s"]
    phase_ms = {name: round(v["ms_per"], 3)
                for name, v in (result["phase_ms"] or {}).items()}
    return {
        "bench": "nano_dsm",
        "arch": "nano",
        "algorithm": "dsm",
        "steps": steps,
        "n_workers": n_workers,
        "tau": tau,
        "tokens": result["tokens"],
        "wall_s": round(wall, 3),
        "steps_per_s": round(steps / wall, 4) if wall > 0 else None,
        "tokens_per_s": round(result["tokens"] / wall, 1) if wall > 0 else None,
        "final_eval": round(result["final_eval"], 4),
        "phase_ms": phase_ms,
        "sign_agree_final": (summary["scalars"].get("sign_agree") or {}).get("last"),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "git_sha": summary.get("git_sha"),
    }


def write_snapshot(snapshot: dict, out_dir: str = ".") -> str:
    path = os.path.join(out_dir, f"BENCH_{snapshot['bench']}.json")
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


if __name__ == "__main__":
    snap = perf_snapshot()
    print(write_snapshot(snap))
