"""Build the EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
artifacts in experiments/dryrun and experiments/roofline."""

from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCH_IDS, INPUT_SHAPES, load_arch


def _load(dirname):
    out = {}
    for f in glob.glob(os.path.join(dirname, "*.json")):
        rec = json.load(open(f))
        out[(rec["arch"], rec["shape"],
             rec.get("mesh") if isinstance(rec.get("mesh"), str)
             else ("multipod" if rec.get("multi_pod") else "singlepod"))] = rec
    return out


def dryrun_table(dirname="experiments/dryrun") -> str:
    recs = _load(dirname)
    lines = [
        "| arch | shape | mesh | status | peak GB/chip | collective wire GB | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        topo = load_arch(arch).TOPO
        for shape in INPUT_SHAPES:
            for mesh in ("singlepod", "multipod"):
                rec = recs.get((arch, shape, mesh))
                if rec is None:
                    if shape == "long_500k" and not topo.supports_long_context:
                        lines.append(
                            f"| {arch} | {shape} | {mesh} | N/A (full-attention; "
                            "spec-sanctioned skip, DESIGN.md) | – | – | – |")
                    continue
                if rec["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | **ERROR** | – | – | – |")
                    continue
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | "
                    f"{rec['memory']['peak_bytes']/1e9:.2f} | "
                    f"{rec['collectives']['wire_bytes']/1e9:.2f} | "
                    f"{rec['compile_s']} |")
    return "\n".join(lines)


def roofline_table(dirname="experiments/roofline") -> str:
    recs = _load(dirname)
    lines = [
        "| arch | shape | t_compute s | t_memory s | t_collective s | dominant | "
        "MODEL_FLOPS/HLO_FLOPS | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        topo = load_arch(arch).TOPO
        for shape in INPUT_SHAPES:
            rec = recs.get((arch, shape, "singlepod"))
            if rec is None:
                if shape == "long_500k" and not topo.supports_long_context:
                    lines.append(f"| {arch} | {shape} | – | – | – | N/A | – | skip |")
                continue
            if rec["status"] != "ok":
                lines.append(f"| {arch} | {shape} | – | – | – | ERROR | – | "
                             f"{rec.get('error','')[:60]} |")
                continue
            note = _note(rec)
            lines.append(
                f"| {arch} | {shape} | {rec['t_compute_s']:.3e} | "
                f"{rec['t_memory_s']:.3e} | {rec['t_collective_s']:.3e} | "
                f"{rec['dominant']} | {rec['useful_flops_ratio']:.2f} | {note} |")
    return "\n".join(lines)


def _note(rec) -> str:
    d = rec["dominant"]
    if d == "collective":
        return "shrink TP degree / overlap collectives"
    if d == "memory":
        return "fuse optimizer passes / cast f32 temps to bf16"
    return "near compute roofline; raise arithmetic intensity"


if __name__ == "__main__":
    print("## Dry-run matrix\n")
    print(dryrun_table())
    print("\n## Roofline (single-pod, per outer step)\n")
    print(roofline_table())
