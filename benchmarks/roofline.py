"""Roofline-term extraction (one per assigned arch x shape x mesh).

Why not read FLOPs straight off the full train-step compile?  XLA's
``cost_analysis`` counts each ``while``-loop body ONCE, and our train step
nests three scans (tau local steps x grad-accum x layer blocks).  We
therefore decompose:

  outer_step_cost = tau*accum * C_micro  +  tau * C_base  +  C_global

  C_micro  — one microbatch value_and_grad (vmapped over workers).  The
             layer-block scan inside is handled by lowering the SAME model
             at L = plen and L = 2*plen layers and solving the linear model
             cost(L) = a + b*L  (exact: scan bodies are layer-homogeneous),
             then evaluating at the full layer count.
  C_base   — one base-optimizer update over the full stacked params
             (elementwise, no scans -> counted exactly).
  C_global — the paper's tau-amortized step: worker all-reduce + global
             sign momentum + re-broadcast (elementwise + collectives,
             no scans -> counted exactly).

Serve shapes (prefill/decode) have only the layer scan -> the two-point
layer extrapolation alone.  Peak HBM always comes from the FULL compile
(launch/dryrun.py), which is also the pass/fail deliverable.

Run:  PYTHONPATH=src python -m benchmarks.roofline --arch all --shape all
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, ARCH_IDS, arch_supports_shape, load_arch
from repro.configs import specs as S
from repro.core import DSMConfig, get_base_optimizer
from repro.core.dsm import _broadcast_workers, global_sign_momentum_step
from repro.distributed import sharding as shd
from repro.launch import dryrun as DR
from repro.launch.mesh import MODEL_PAR, make_production_mesh, serving_mesh, training_mesh
from repro.models import transformer as T


def _cost_of(lowered) -> dict:
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = DR.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": float(coll["wire_bytes"]),
        "coll": coll,
    }


def _lin(c1: dict, c2: dict, l1: int, l2: int, l_full: int) -> dict:
    """cost(L) = a + b*L from two points, evaluated at l_full."""
    out = {}
    for k in ("flops", "bytes", "wire"):
        b = (c2[k] - c1[k]) / (l2 - l1)
        a = c1[k] - b * l1
        # clamp: tiny per-layer wire can extrapolate below zero when the
        # two-point costs are dominated by layer-independent terms
        out[k] = max(a + b * l_full, 0.0)
    return out


def _reduced(cfg, n_layers: int, enc_layers: int = None):
    kw = {"n_layers": n_layers}
    if cfg.family == "encdec":
        kw["enc_layers"] = enc_layers if enc_layers is not None else n_layers
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# Train decomposition
# ---------------------------------------------------------------------------

def _train_micro_cost(cfg, topo, shape, mesh, W, zero, n_layers):
    """Lower one microbatch value_and_grad at a reduced layer count."""
    rcfg = _reduced(cfg, n_layers)
    rep = () if topo.attn_tp else ("wq", "wk", "wv", "wo")
    aps = S.abstract_params(rcfg)
    wparams = jax.eval_shape(lambda p: _broadcast_workers(p, W), aps)
    full = S.train_batch_specs(cfg, topo, shape, W)
    micro = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((W,) + l.shape[3:], l.dtype), full
    )

    wspec = shd.to_named(
        shd.param_pspecs(wparams, model=MODEL_PAR, zero=zero, worker_axis=True,
                         replicate_names=rep), mesh)
    mspec = shd.to_named(
        jax.tree.map(lambda l: P(*(("worker",) + (None,) * (len(l.shape) - 1))), micro,
                     is_leaf=lambda x: hasattr(x, "shape")), mesh)

    # unroll=True: layer costs must scale with L for the two-point fit
    # (XLA cost_analysis counts while bodies once)
    loss = lambda p, b: T.loss_fn(p, b, rcfg, remat=topo.remat, unroll=True,
                                  remat_policy=getattr(topo, "remat_policy", "full"))

    def micro_grad(params_w, mb):
        return jax.vmap(jax.value_and_grad(loss))(params_w, mb)

    out_sh = (NamedSharding(mesh, P("worker")), wspec)
    with mesh:
        lowered = jax.jit(
            micro_grad, in_shardings=(wspec, mspec), out_shardings=out_sh
        ).lower(wparams, micro)
    return _cost_of(lowered)


def _train_base_cost(cfg, topo, mesh, W, zero):
    """One base-optimizer direction+update over the FULL stacked params."""
    base_opt = get_base_optimizer(topo.base_opt)
    aps = S.abstract_params(cfg)
    wparams = jax.eval_shape(lambda p: _broadcast_workers(p, W), aps)
    bstate = jax.eval_shape(lambda p: jax.vmap(base_opt.init)(p), wparams)

    wspec = shd.to_named(
        shd.param_pspecs(wparams, model=MODEL_PAR, zero=zero, worker_axis=True), mesh)
    bspec = shd.to_named(
        shd.param_pspecs(bstate, model=MODEL_PAR, zero=zero, worker_axis=True), mesh)

    def base_step(params_w, grads_w, bs_w):
        def per_worker(p, g, bs):
            d, new_bs = base_opt.direction(g, bs, p, jnp.zeros((), jnp.int32))
            new_p = jax.tree.map(
                lambda x, dd: (x.astype(jnp.float32)
                               - 3e-4 * dd.astype(jnp.float32)).astype(x.dtype),
                p, d)
            return new_p, new_bs

        return jax.vmap(per_worker)(params_w, grads_w, bs_w)

    with mesh:
        lowered = jax.jit(
            base_step, in_shardings=(wspec, wspec, bspec),
            out_shardings=(wspec, bspec),
        ).lower(wparams, wparams, bstate)
    return _cost_of(lowered)


def _train_global_cost(cfg, topo, mesh, W, zero, zero_global_buffers=False):
    """The paper's global step: all-reduce over workers + sign momentum + sync."""
    aps = S.abstract_params(cfg)
    wparams = jax.eval_shape(lambda p: _broadcast_workers(p, W), aps)
    m_sds = jax.eval_shape(
        lambda p: jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), p), aps)

    wspec = shd.to_named(
        shd.param_pspecs(wparams, model=MODEL_PAR, zero=zero, worker_axis=True), mesh)
    gz_axes = ("worker", "zero") if zero_global_buffers else ("zero",)
    gz = zero * (W if zero_global_buffers else 1)
    gspec = shd.to_named(
        shd.param_pspecs(aps, model=MODEL_PAR, zero=gz, zero_axes=gz_axes), mesh)
    mspec = shd.to_named(
        shd.param_pspecs(m_sds, model=MODEL_PAR, zero=gz, zero_axes=gz_axes), mesh)

    dsm_cfg = DSMConfig(tau=topo.tau)

    def gstep(x0, m, params_w):
        x_tau = jax.tree.map(lambda p: p.mean(axis=0), params_w)  # THE all-reduce
        new_x0, new_m = global_sign_momentum_step(
            x0, m, x_tau, jnp.float32(3e-4), dsm_cfg)
        return new_x0, new_m, _broadcast_workers(new_x0, W)

    with mesh:
        lowered = jax.jit(
            gstep, in_shardings=(gspec, mspec, wspec),
            out_shardings=(gspec, mspec, wspec),
        ).lower(aps, m_sds, wparams)
    return _cost_of(lowered)


def roofline_train(arch_id: str, shape_name: str, multi_pod: bool,
                   zero_global_buffers: bool = False, overrides: dict = None,
                   cfg_overrides: dict = None) -> dict:
    mod = load_arch(arch_id)
    cfg, topo = mod.FULL, mod.TOPO
    if overrides:
        topo = dataclasses.replace(topo, **{k: v for k, v in overrides.items()
                                            if hasattr(topo, k)})
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    base = make_production_mesh(multi_pod=multi_pod)
    W = topo.n_workers_multi if multi_pod else topo.n_workers_single
    mesh = training_mesh(base, W)
    zero = mesh.devices.shape[1]

    plen = len(cfg.pattern)
    c1 = _train_micro_cost(cfg, topo, shape, mesh, W, zero, plen)
    c2 = _train_micro_cost(cfg, topo, shape, mesh, W, zero, 2 * plen)
    micro = _lin(c1, c2, plen, 2 * plen, cfg.n_layers)
    basec = _train_base_cost(cfg, topo, mesh, W, zero)
    glob = _train_global_cost(cfg, topo, mesh, W, zero, zero_global_buffers)

    tau, acc = topo.tau, topo.grad_accum
    total = {
        k: tau * acc * micro[k] + tau * basec[k] + glob[k]
        for k in ("flops", "bytes", "wire")
    }
    tokens = shape.global_batch * shape.seq_len * tau  # per outer step
    model_flops = 6 * S.active_param_count(cfg) * tokens / mesh.devices.size

    return _terms(total, model_flops, mesh, arch_id, shape_name, multi_pod,
                  parts={"micro": micro, "base": basec, "global": glob,
                         "tau": tau, "accum": acc})


# ---------------------------------------------------------------------------
# Serve decomposition (layer extrapolation only)
# ---------------------------------------------------------------------------

def _serve_cost(arch_id, cfg, shape_name, mesh, multi_pod, n_layers):
    rcfg = _reduced(cfg, n_layers)
    kind = INPUT_SHAPES[shape_name].kind
    # rebuild with the reduced cfg via a patched arch module view
    import types

    mod = types.SimpleNamespace(FULL=rcfg, TOPO=load_arch(arch_id).TOPO)
    orig = DR.load_arch
    DR.load_arch = lambda a: mod  # scoped monkey-patch
    try:
        if kind == "prefill":
            lowered, _ = DR.build_prefill(arch_id, shape_name, multi_pod, unroll=True)
        else:
            lowered, _ = DR.build_decode(arch_id, shape_name, multi_pod, unroll=True)
    finally:
        DR.load_arch = orig
    return _cost_of(lowered)


def roofline_serve(arch_id: str, shape_name: str, multi_pod: bool) -> dict:
    mod = load_arch(arch_id)
    cfg = mod.FULL
    base = make_production_mesh(multi_pod=multi_pod)
    mesh = serving_mesh(base)
    plen = len(cfg.pattern)
    c1 = _serve_cost(arch_id, cfg, shape_name, mesh, multi_pod, plen)
    c2 = _serve_cost(arch_id, cfg, shape_name, mesh, multi_pod, 2 * plen)
    total = _lin(c1, c2, plen, 2 * plen, cfg.n_layers)

    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * S.active_param_count(cfg) * tokens / mesh.devices.size
    else:
        tokens = shape.global_batch
        model_flops = 2 * S.active_param_count(cfg) * tokens / mesh.devices.size
    return _terms(total, model_flops, mesh, arch_id, shape_name, multi_pod, parts={})


def _terms(total, model_flops, mesh, arch_id, shape_name, multi_pod, parts):
    t_c = total["flops"] / DR.PEAK_FLOPS
    t_m = total["bytes"] / DR.HBM_BW
    t_n = total["wire"] / DR.ICI_BW
    dom = max([("compute", t_c), ("memory", t_m), ("collective", t_n)],
              key=lambda kv: kv[1])[0]
    return {
        "arch": arch_id, "shape": shape_name,
        "mesh": "multipod" if multi_pod else "singlepod",
        "hlo_flops_per_chip": total["flops"],
        "hlo_bytes_per_chip": total["bytes"],
        "wire_bytes_per_chip": total["wire"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_n,
        "dominant": dom,
        "model_flops_per_chip": model_flops,
        "useful_flops_ratio": model_flops / total["flops"] if total["flops"] else 0.0,
        "roofline_bound_s": max(t_c, t_m, t_n),
        "parts": parts,
        "n_chips": mesh.devices.size,
        "status": "ok",
    }


def run_one(arch_id, shape_name, multi_pod, outdir, **kw):
    t0 = time.time()
    try:
        if INPUT_SHAPES[shape_name].kind == "train":
            rec = roofline_train(arch_id, shape_name, multi_pod, **kw)
        else:
            rec = roofline_serve(arch_id, shape_name, multi_pod)
    except Exception as e:  # noqa: BLE001
        import traceback

        rec = {"arch": arch_id, "shape": shape_name,
               "mesh": "multipod" if multi_pod else "singlepod",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
    rec["wall_s"] = round(time.time() - t0, 1)
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        tag = f"{arch_id}.{shape_name}.{'multipod' if multi_pod else 'singlepod'}"
        with open(os.path.join(outdir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--outdir", default="experiments/roofline")
    ap.add_argument("--zero-global-buffers", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch_id in archs:
        mod = load_arch(arch_id)
        for shape_name in shapes:
            if not arch_supports_shape(mod.FULL, mod.TOPO, shape_name):
                print(f"SKIP {arch_id} x {shape_name}")
                continue
            for mp in meshes:
                kw = {}
                if (INPUT_SHAPES[shape_name].kind == "train"
                        and args.zero_global_buffers):
                    kw["zero_global_buffers"] = True
                rec = run_one(arch_id, shape_name, mp, args.outdir, **kw)
                if rec["status"] == "ok":
                    print(f"OK  {arch_id:28s} {shape_name:12s} dom={rec['dominant']:10s} "
                          f"tc={rec['t_compute_s']:.3e} tm={rec['t_memory_s']:.3e} "
                          f"tn={rec['t_collective_s']:.3e} "
                          f"useful={rec['useful_flops_ratio']:.2f} ({rec['wall_s']}s)",
                          flush=True)
                else:
                    print(f"ERR {arch_id:28s} {shape_name:12s} {rec['error'][:180]}",
                          flush=True)


if __name__ == "__main__":
    main()
