"""Benchmark harness: one function per paper table + kernels + comm volume.

Prints ``name,value,derived`` CSV rows.  ``--quick`` shrinks sweeps/steps.
Roofline terms (deliverable g) live in benchmarks/roofline.py (they need
the 512-device dry-run env and run as a separate process).
"""

import argparse
import json
import os


def _emit(name, value, derived=""):
    print(f"{name},{value},{derived}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="all",
                    help="comma list: table2,table3,table45,table6,curves,comm,"
                         "kernels,perf")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(","))

    def want(x):
        return "all" in only or x in only

    from benchmarks import comm, kernel_bench, tables

    if want("perf"):
        from benchmarks import perf

        snap = perf.perf_snapshot(steps=8 if args.quick else 12)
        path = perf.write_snapshot(snap)
        _emit("perf_steps_per_s", f"{snap['steps_per_s']:.3f}", path)
        _emit("perf_tokens_per_s", f"{snap['tokens_per_s']:.0f}",
              ";".join(f"{k}={v}ms" for k, v in sorted(snap["phase_ms"].items())))

    if want("kernels"):
        for fn in (kernel_bench.bench_dsm_kernel, kernel_bench.bench_adamw_kernel,
                   kernel_bench.bench_interpret_correct):
            name, us, derived = fn()
            _emit(name, f"{us:.1f}us", derived)

    if want("comm"):
        for arch in ("gpt2_medium", "deepseek_67b", "llama4_maverick_400b_a17b"):
            for algo in ("dsm", "perstep", "mv_signsgd"):
                r = comm.bytes_per_outer_step(arch, algo, tau=12)
                _emit(f"comm_{arch}_{algo}",
                      f"{r['wire_bytes_per_outer']/1e9:.3f}GB",
                      f"reduction={r['reduction_vs_perstep']:.1f}x")

    os.makedirs("experiments", exist_ok=True)
    results = {}
    for tname, fn in (("table2", tables.table2), ("table3", tables.table3),
                      ("table45", tables.table45), ("table6", tables.table6),
                      ("table_noise", tables.table_noise)):
        if not want(tname):
            continue
        rows = fn(quick=args.quick)
        results[tname] = rows
        for name, red, val, commr, params in rows:
            _emit(f"{tname}_{name}", f"{val:.4f}",
                  f"comm_red={red};rounds={commr};{params}")

    if want("curves"):
        cur = tables.curves(quick=args.quick)
        results["curves"] = cur
        with open("experiments/curves.json", "w") as f:
            json.dump(cur, f)
        for algo, pts in cur.items():
            _emit(f"curve_{algo}_final", f"{pts[-1][3]:.4f}",
                  f"comm_rounds={pts[-1][1]}")

    with open("experiments/bench_results.json", "w") as f:
        json.dump({k: v for k, v in results.items() if k != "curves"}, f, indent=1,
                  default=str)


if __name__ == "__main__":
    main()
