"""Paper-table analogs at CPU scale (one function per paper table).

The paper trains GPT-2 124M-770M for 100k steps on OpenWebText across 8-16
GPU workers.  Offline/CPU we reproduce every comparison on a nano GPT over
a structured Markov corpus with simulated workers — same algorithms, same
protocol (tune global LR for Alg. 1, momentum+LR for SlowMo), scaled down.

Paper claims being checked:
  T2: Alg.1 beats SlowMo at every tau; both trail per-step AdamW slightly.
  T3: same ordering with Sophia as the base optimizer.
  T4: Lookahead (n=1) improves on plain AdamW.
  T5: signed Lookahead (n=1) improves on plain AdamW.
  T6: signed SlowMo sits between SlowMo and Alg.1; global AdamW ~ SlowMo.
"""

from __future__ import annotations


import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import MarkovCorpus
from repro.train.trainer import TrainSettings, run_training

NANO = ModelConfig(
    name="nano_gpt", family="lm", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=64, head_dim=16, mlp_gated=False,
    act="gelu", dtype="float32", param_dtype="float32", vocab_pad_to=64,
)

_CORPUS = None


def corpus():
    global _CORPUS
    if _CORPUS is None:
        _CORPUS = MarkovCorpus(NANO.vocab_size, branch=4, seed=7)
    return _CORPUS


def _settings(**kw) -> TrainSettings:
    base = dict(
        n_workers=4, tau=8, steps=60, b_micro=8, seq=128, peak_lr=1e-2,
        warmup=5, eval_every=30, heterogeneous=True,
        # CPU-scale horizon is ~1000x shorter than the paper's 100k steps;
        # momentum time-constants are scaled accordingly (beta 0.9/0.95
        # instead of Lion's 0.95/0.98). See EXPERIMENTS.md SScale-notes.
        dsm_beta1=0.9, dsm_beta2=0.95,
    )
    base.update(kw)
    return TrainSettings(**base)


def _best(results):
    return min(results, key=lambda r: r["final_eval"])


def run_algo(algo, steps, tau, sweep, **kw):
    """Tune per the paper's protocol; return the best run + its config."""
    out = []
    for params in sweep:
        s = _settings(algorithm=algo, steps=steps, tau=tau, **params, **kw)
        r = run_training(NANO, s, corpus())
        r["sweep_params"] = params
        out.append(r)
    return _best(out)


def table2(steps=60, taus=(4, 8, 12), quick=False):
    """Alg.1 vs SlowMo vs per-step AdamW across communication intervals."""
    if quick:
        taus, steps = (4,), 24
    rows = []
    ps = run_algo("perstep", steps, taus[0], [dict()])
    rows.append(("adamw_perstep", "n/a", ps["final_eval"], ps["comm_rounds"], {}))
    for tau in taus:
        dsm = run_algo("dsm", steps, tau,
                       [dict(global_lr=g) for g in ((0.5,) if quick else (0.5, 1.0, 2.0))])
        slowmo = run_algo("slowmo", steps, tau,
                          [dict(slow_beta=b, global_lr=1.0)
                           for b in ((0.5,) if quick else (0.4, 0.6, 0.8))])
        improv = float(np.exp(slowmo["final_eval"] - dsm["final_eval"]) - 1) * 100
        rows.append((f"dsm_tau{tau}", f"{tau}x", dsm["final_eval"],
                     dsm["comm_rounds"], dsm["sweep_params"]))
        rows.append((f"slowmo_tau{tau}", f"{tau}x", slowmo["final_eval"],
                     slowmo["comm_rounds"], slowmo["sweep_params"]))
        rows.append((f"improv_tau{tau}_pct", f"{tau}x", improv, 0, {}))
    return rows


def table3(steps=60, tau=8, quick=False):
    """Sophia as the base optimizer."""
    if quick:
        steps = 24
    sp = run_algo("perstep", steps, tau, [dict(base_opt="sophia")])
    dsm = run_algo("dsm", steps, tau,
                   [dict(base_opt="sophia", global_lr=g)
                    for g in ((0.5,) if quick else (0.5, 1.0))])
    sm = run_algo("slowmo", steps, tau,
                  [dict(base_opt="sophia", slow_beta=b)
                   for b in ((0.5,) if quick else (0.4, 0.6))])
    return [
        ("sophia_perstep", "n/a", sp["final_eval"], sp["comm_rounds"], {}),
        (f"dsm_sophia_tau{tau}", f"{tau}x", dsm["final_eval"], dsm["comm_rounds"],
         dsm["sweep_params"]),
        (f"slowmo_sophia_tau{tau}", f"{tau}x", sm["final_eval"], sm["comm_rounds"],
         sm["sweep_params"]),
    ]


def table45(steps=60, tau=8, quick=False):
    """Lookahead / signed Lookahead with n=1 (paper Tables 4-5)."""
    if quick:
        steps = 24
    base = run_algo("perstep", steps, 1, [dict(n_workers=1)])
    la = run_algo("lookahead", steps, tau,
                  [dict(n_workers=1, slow_beta=b, global_lr=1.0)
                   for b in ((0.2,) if quick else (0.1, 0.2))])
    sla = run_algo("signed_lookahead", steps, tau,
                   [dict(n_workers=1, slow_beta=b, global_lr=0.3)
                    for b in ((0.6,) if quick else (0.6, 0.8))])
    return [
        ("adamw_n1", "n/a", base["final_eval"], base["comm_rounds"], {}),
        ("lookahead", "n/a", la["final_eval"], la["comm_rounds"], la["sweep_params"]),
        ("signed_lookahead", "n/a", sla["final_eval"], sla["comm_rounds"],
         sla["sweep_params"]),
    ]


def table6(steps=60, tau=8, quick=False):
    """signed SlowMo and global AdamW ablations (paper Table 6)."""
    if quick:
        steps = 24
    sm = run_algo("slowmo", steps, tau,
                  [dict(slow_beta=b) for b in ((0.5,) if quick else (0.4, 0.6))])
    ssm = run_algo("signed_slowmo", steps, tau,
                   [dict(slow_beta=b, global_lr=g)
                    for b in ((0.5,) if quick else (0.5, 0.8))
                    for g in ((0.005,) if quick else (0.005, 0.02))])
    ga = run_algo("global_adamw", steps, tau, [dict(global_lr=1.0)])
    dsm = run_algo("dsm", steps, tau,
                   [dict(global_lr=g) for g in ((0.5,) if quick else (0.5, 1.0))])
    return [
        (f"slowmo_tau{tau}", f"{tau}x", sm["final_eval"], sm["comm_rounds"], sm["sweep_params"]),
        (f"signed_slowmo_tau{tau}", f"{tau}x", ssm["final_eval"], ssm["comm_rounds"],
         ssm["sweep_params"]),
        (f"global_adamw_tau{tau}", f"{tau}x", ga["final_eval"], ga["comm_rounds"], {}),
        (f"dsm_tau{tau}", f"{tau}x", dsm["final_eval"], dsm["comm_rounds"], dsm["sweep_params"]),
    ]


def curves(steps=60, tau=8, quick=False):
    """Fig. 1/2 analog: loss vs communication rounds / computation rounds."""
    if quick:
        steps = 24
    out = {}
    for algo, kw in [("dsm", dict(global_lr=0.4)),
                     ("slowmo", dict(slow_beta=0.6)),
                     ("perstep", dict())]:
        s = _settings(algorithm=algo, steps=steps, tau=tau, **kw)
        r = run_training(NANO, s, corpus())
        comm_per_step = tau if algo == "perstep" else 1
        out[algo] = [
            (t + 1, (t + 1) * comm_per_step, (t + 1) * tau, loss)
            for t, loss in enumerate(r["history"])
        ]
    return out


def table_noise(steps=100, tau=8, quick=False):
    """Large-noise regime (theory Remark 2): DSM's strongest claim at CPU
    scale — sign momentum beats SlowMo when local gradients are noisy."""
    if quick:
        steps = 60  # cheap (batch-1, seq-32); keep enough horizon to separate
    kw = dict(b_micro=1, seq=32)
    dsm = run_algo("dsm", steps, tau, [dict(global_lr=1.0)], **kw)
    sm = run_algo("slowmo", steps, tau,
                  [dict(slow_beta=b) for b in ((0.5,) if quick else (0.5, 0.7))], **kw)
    improv = float(np.exp(sm["final_eval"] - dsm["final_eval"]) - 1) * 100
    return [
        ("dsm_noisy", f"{tau}x", dsm["final_eval"], dsm["comm_rounds"], dsm["sweep_params"]),
        ("slowmo_noisy", f"{tau}x", sm["final_eval"], sm["comm_rounds"], sm["sweep_params"]),
        ("improv_noisy_pct", f"{tau}x", improv, 0, {}),
    ]
