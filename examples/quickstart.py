"""Quickstart: train a nano GPT with Distributed Sign Momentum (Alg. 1)
and compare against SlowMo at the same communication budget.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs.base import ModelConfig
from repro.data.pipeline import MarkovCorpus
from repro.train.trainer import TrainSettings, run_training

CFG = ModelConfig(
    name="quickstart", family="lm", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=64, head_dim=16, mlp_gated=False,
    act="gelu", dtype="float32", param_dtype="float32", vocab_pad_to=64,
)


def main():
    corpus = MarkovCorpus(CFG.vocab_size, branch=4, seed=7)
    common = dict(n_workers=4, tau=8, steps=30, b_micro=8, seq=128,
                  peak_lr=1e-2, warmup=5, eval_every=10)

    print("== Algorithm 1 (DSM): AdamW local steps + global sign momentum ==")
    r_dsm = run_training(
        CFG, TrainSettings(algorithm="dsm", global_lr=0.3, **common),
        corpus, log=print)

    print("== SlowMo baseline (same tau, same tokens) ==")
    r_sm = run_training(
        CFG, TrainSettings(algorithm="slowmo", slow_beta=0.6, **common),
        corpus, log=print)

    print(f"\nDSM    final eval loss: {r_dsm['final_eval']:.4f} "
          f"({r_dsm['comm_rounds']} comm rounds)")
    print(f"SlowMo final eval loss: {r_sm['final_eval']:.4f} "
          f"({r_sm['comm_rounds']} comm rounds)")
    print(f"both use {r_dsm['comm_rounds']}x fewer all-reduces than per-step DP")


if __name__ == "__main__":
    main()
