"""Theory demo: the randomized sign operators (paper eqs. 9-10) behind
Theorems 1-2 — unbiasedness E[S_r(v)] = v/B and the O(1/sqrt(T)) style
decay of the gradient norm when training with the randomized variant.

Run:  PYTHONPATH=src python examples/randomized_sign_theory.py
"""

import jax
import jax.numpy as jnp

from repro.core import randomized_sign_pm, randomized_sign_zero
from repro.configs.base import ModelConfig
from repro.data.pipeline import MarkovCorpus
from repro.train.trainer import TrainSettings, run_training


def main():
    key = jax.random.PRNGKey(0)
    v = jax.random.uniform(key, (512,), minval=-1, maxval=1)
    B = float(jnp.linalg.norm(v)) * 1.2
    keys = jax.random.split(key, 4000)
    for name, op in (("eq9 +-sign", randomized_sign_pm),
                     ("eq10 zero/sign", randomized_sign_zero)):
        mean = jax.vmap(lambda k: op(v, k, B))(keys).mean(0)
        err = float(jnp.max(jnp.abs(mean - v / B)))
        print(f"{name}: max |E[S_r(v)] - v/B| = {err:.4f}  (Lemma 1)")

    cfg = ModelConfig(name="nano", family="lm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64,
                      head_dim=16, mlp_gated=False, act="gelu",
                      dtype="float32", param_dtype="float32", vocab_pad_to=64)
    corpus = MarkovCorpus(64, branch=4, seed=7)
    for mode in ("sign", "rand_pm"):
        s = TrainSettings(algorithm="dsm", sign_mode=mode, n_workers=4, tau=4,
                          steps=20, b_micro=8, seq=128, peak_lr=1e-2,
                          global_lr=0.3, warmup=4, eval_every=20)
        r = run_training(cfg, s, corpus)
        print(f"DSM sign_mode={mode:8s}: final eval {r['final_eval']:.4f}")


if __name__ == "__main__":
    main()
