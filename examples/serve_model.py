"""Serving example: train a byte-level model on this repo's own source code
with DSM, then serve batched greedy completions through the production
decode path (prefill + KV-cache decode_step — the same functions the
decode_32k / long_500k dry-runs lower).

Run:  PYTHONPATH=src python examples/serve_model.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import TextCorpus
from repro.train.trainer import TrainSettings, run_training
from repro.train.serve import generate

CFG = ModelConfig(
    name="bytelm", family="lm", n_layers=3, d_model=96, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=256, head_dim=24,
    pattern=("swa:dense", "swa:dense", "attn:dense"), window=64,
    dtype="float32", param_dtype="float32", vocab_pad_to=256,
)


def main():
    corpus = TextCorpus(root=".", pattern="src/**/*.py")
    s = TrainSettings(algorithm="dsm", n_workers=2, tau=8, steps=40,
                      b_micro=8, seq=192, peak_lr=1e-2, warmup=6,
                      global_lr=0.3, eval_every=10)
    print("training byte-level LM on repro's own source ...")
    r = run_training(CFG, s, corpus, log=print)
    params = r["state"].x0

    prompts = [b"def make_", b"import ja", b"class Mod", b"    return"]
    width = max(len(p) for p in prompts)
    batch = np.stack([
        np.frombuffer(p.rjust(width, b" "), dtype=np.uint8).astype(np.int32)
        for p in prompts
    ])
    toks, stats = generate(params, CFG, jnp.asarray(batch), max_new_tokens=24)
    print(f"\nbatched decode: {stats['tok_per_s']:.1f} tok/s "
          f"(prefill {stats['prefill_s']:.2f}s)")
    for p, t in zip(prompts, np.asarray(toks)):
        completion = bytes(int(x) % 256 for x in t).decode("latin1")
        print(f"  {p.decode():>12s} -> {completion!r}")


if __name__ == "__main__":
    main()
