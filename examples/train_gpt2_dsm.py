"""End-to-end driver: pre-train a GPT-2-small-family model from scratch with
Algorithm 1, mirroring the paper's §4 protocol (AdamW base optimizer,
cosine LR with warmup, Lion betas for the global step, tau=12).

Defaults are CPU-sized (reduced width, 120 outer steps). On a real cluster,
raise --layers/--d-model/--seq to the paper's 124M config (12L/768) — the
training code is identical; the dry-run (launch/dryrun.py) proves the
full-size sharded lowering.

Run:  PYTHONPATH=src python examples/train_gpt2_dsm.py --steps 120
"""

import argparse

from repro.configs.base import ModelConfig
from repro.data.pipeline import MarkovCorpus
from repro.train.trainer import TrainSettings, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--tau", type=int, default=12)
    ap.add_argument("--n-workers", type=int, default=8)
    ap.add_argument("--peak-lr", type=float, default=5e-3)
    ap.add_argument("--global-lr", type=float, default=0.3)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="gpt2_family", family="lm", n_layers=args.layers,
        d_model=args.d_model, n_heads=max(args.d_model // 32, 1),
        n_kv_heads=max(args.d_model // 32, 1), d_ff=4 * args.d_model,
        vocab_size=256, head_dim=32, mlp_gated=False, act="gelu",
        tie_embeddings=True, dtype="float32", param_dtype="float32",
        vocab_pad_to=256,
    )
    corpus = MarkovCorpus(cfg.vocab_size, branch=8, seed=3)

    s = TrainSettings(
        algorithm="dsm", base_opt="adamw", n_workers=args.n_workers,
        tau=args.tau, steps=args.steps, b_micro=2, seq=args.seq,
        peak_lr=args.peak_lr, warmup=max(args.steps // 10, 2),
        global_lr=args.global_lr,
        dsm_beta1=0.95, dsm_beta2=0.98, dsm_wd=0.1,  # paper's Lion params
        eval_every=max(args.steps // 6, 1),
    )
    r = run_training(cfg, s, corpus, log=print)
    print(f"\nfinal eval loss {r['final_eval']:.4f}; "
          f"{r['tokens']/1e6:.1f}M tokens, {r['comm_rounds']} comm rounds "
          f"({args.tau}x fewer than per-step data parallel)")


if __name__ == "__main__":
    main()
