"""Static analysis & sanitizers for the DSM training system.

Three layers (see docs/analysis.md):

  * ``hlo_audit``  — lower any jitted step to compiled HLO, count the
    collective ops with their shapes, and check them against the per-phase
    budgets derived from the analytic model in ``benchmarks/comm.py``.
    The paper's claim IS a collective budget (one reduction per tau local
    steps, none inside them); this makes it machine-checked.
  * ``lint``       — RPR0xx AST rules for the bug classes nothing else
    catches statically: reused ``jax.random`` keys, host syncs inside
    jit-reachable code, Python control flow on traced values, mutable
    config defaults.  No jax import — runs anywhere, fast.
  * ``sanitize``   — opt-in runtime guards for the hot loop: transfer
    guard, log_compiles-based recompilation counter, debug_nans tier.

CLI: ``python -m repro.analysis {audit,lint} [--json]``.

This package intentionally does NOT import jax at package level, so the
lint layer stays usable in environments without a working jax install.
"""

from repro.analysis.lint import Finding, lint_paths, lint_source  # noqa: F401
