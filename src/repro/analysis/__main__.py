"""``python -m repro.analysis`` — audit / lint CLI (docs/analysis.md).

  audit  — compile the dense / device-parallel / ZeRO-sharded outer steps
           and the bare local phase, parse their collectives, and check
           them against the budgets derived from benchmarks/comm.py.
           Forces a multi-device host (``--devices``, default 8) BEFORE
           jax is imported so the mesh is not degenerate.
  lint   — run the RPR0xx rules over files/directories.

Both exit nonzero on findings/violations; ``--json`` prints a machine-
readable report (CI uploads the audit report as an artifact).
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _cmd_audit(args: argparse.Namespace) -> int:
    if args.devices > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()

    import jax

    from repro.analysis.hlo_audit import standard_audit

    reports = standard_audit(n_workers=args.n_workers, tau=args.tau,
                             self_test=args.self_test)
    degenerate = jax.device_count() < 2
    ok = True
    for r in reports:
        expect_fail = r.name.startswith("self_test")
        passed = (not r.passed) if expect_fail else r.passed
        ok &= passed
        if expect_fail and not r.passed:
            # the planted collective was caught: the auditor is live
            r.violations = [f"(expected) {v}" for v in r.violations]
    if degenerate and not args.allow_degenerate:
        ok = False

    payload = {
        "n_devices": jax.device_count(),
        "degenerate": degenerate,
        "passed": bool(ok),
        "reports": [r.to_json() for r in reports],
    }
    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2)
    if args.json:
        print(json.dumps(payload, indent=2))
    else:
        for r in reports:
            counts = ", ".join(f"{k}={v}" for k, v in sorted(r.counts.items())) \
                or "no collectives"
            status = "PASS" if r.passed else "FAIL"
            if r.name.startswith("self_test"):
                status = "PASS (caught)" if not r.passed else \
                    "FAIL (planted collective NOT caught)"
            print(f"[{status}] {r.name:<32} {counts}")
            for v in r.violations:
                print(f"         {v}")
        if degenerate and not args.allow_degenerate:
            print("FAIL: single-device host — the mesh is degenerate and no "
                  "collectives compile; rerun with --devices >= 2 before jax "
                  "is imported (or pass --allow-degenerate)")
        print("audit:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def _cmd_lint(args: argparse.Namespace) -> int:
    from repro.analysis.lint import RULES, lint_paths

    findings = lint_paths(args.paths)
    if args.select:
        keep = {r.strip() for r in args.select.split(",")}
        unknown = keep - set(RULES) - {"RPR000"}
        if unknown:
            print(f"unknown rule(s): {sorted(unknown)}", file=sys.stderr)
            return 2
        findings = [f for f in findings if f.rule in keep]
    if args.json:
        print(json.dumps([f.to_json() for f in findings], indent=2))
    else:
        for f in findings:
            print(f)
        print(f"{len(findings)} finding(s)")
    return 1 if findings else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)

    ap_audit = sub.add_parser("audit", help="collective-budget HLO audit")
    ap_audit.add_argument("--devices", type=int, default=8,
                          help="forced host device count (set before jax "
                               "import; default 8)")
    ap_audit.add_argument("--n-workers", type=int, default=4)
    ap_audit.add_argument("--tau", type=int, default=2)
    ap_audit.add_argument("--self-test", action="store_true",
                          help="also audit a step with a PLANTED extra "
                               "all-reduce, which must fail")
    ap_audit.add_argument("--allow-degenerate", action="store_true",
                          help="do not fail on a single-device host")
    ap_audit.add_argument("--json", action="store_true")
    ap_audit.add_argument("--out", default=None,
                          help="also write the JSON report to this file")
    ap_audit.set_defaults(fn=_cmd_audit)

    ap_lint = sub.add_parser("lint", help="RPR0xx custom AST lint")
    ap_lint.add_argument("paths", nargs="+")
    ap_lint.add_argument("--select", default=None,
                         help="comma-separated rule ids to keep")
    ap_lint.add_argument("--json", action="store_true")
    ap_lint.set_defaults(fn=_cmd_lint)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
