"""HLO collective auditor: machine-checked communication budgets.

The paper's headline claim is a *collective budget*: sign momentum
communicates once per tau local steps (one worker reduction + , when
ZeRO-sharded, one gather), and the tau local steps themselves are
communication-free.  ``benchmarks/comm.py`` models that analytically;
this module checks that the COMPILED program agrees, by lowering any
jitted step to its post-partitioning HLO text, parsing every collective
op with its shape, and comparing op counts and payload bytes against the
declared per-phase budget.

Budget semantics (``benchmarks.comm.phase_collective_budget``):

  * a LOGICAL reduction round may lower as ``reduce-scatter`` on
    collective-capable backends or as ``all-reduce`` (+ local slice) under
    the CPU partitioner — one equivalence class, bounded together.  A
    *stray* extra reduction (a planted psum, an accidental re-reduce)
    exceeds the per-leaf ceiling either way.
  * XLA lowers a logical round leafwise, so ceilings are
    ``rounds * (n_param_leaves + n_metric_reductions)`` ops and
    ``rounds * payload_slack * payload_bytes`` bytes.
  * op kinds outside the declared classes (``all-to-all``,
    ``collective-permute``) never appear in Algorithm 1's outer step and
    any occurrence is a violation.

``standard_audit()`` runs the matrix the CI gate uses: the dense
(vmapped), device-parallel, and ZeRO-sharded outer steps plus the bare
local phase, on a nano model over the host training mesh.  Run it via
``python -m repro.analysis audit`` (which forces a multi-device host so
the mesh is not degenerate).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Sequence

PyTree = Any

COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
)

# `all-reduce(`, `all-reduce-start(`; never `all-reduce-done(` (the async
# completion carries no payload of its own).
_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|\S+)\s+(?P<kind>%s)(?:-start)?\("
    % "|".join(COLLECTIVE_KINDS)
)

_SHAPE_RE = re.compile(r"(?P<dtype>[a-z]+\d*)\[(?P<dims>[\d,]*)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


def _shape_bytes(shape: str) -> int:
    """Payload bytes of an HLO shape string (tuples sum their components)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape):
        n = 1
        dims = m.group("dims")
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(m.group("dtype"), 4)
    return total


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    kind: str    # canonical kind, e.g. "all-reduce"
    shape: str   # HLO result shape text, e.g. "f32[2,64,16]{2,1,0}"
    bytes: int   # payload bytes of the result
    line: int    # 1-based line in the HLO text


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    """Every collective op in a compiled HLO module, with result shapes."""
    ops = []
    for i, line in enumerate(hlo_text.splitlines(), start=1):
        m = _OP_RE.search(line)
        if m:
            shape = m.group("shape")
            ops.append(CollectiveOp(kind=m.group("kind"), shape=shape,
                                    bytes=_shape_bytes(shape), line=i))
    return ops


# ---------------------------------------------------------------------------
# Budgets
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CollectiveBudget:
    """Per-phase ceiling on the collectives a compiled step may contain."""

    phase: str
    max_reduce_ops: int
    max_gather_ops: int
    max_reduce_bytes: int
    max_gather_bytes: int
    reduce_class: tuple = ("all-reduce", "reduce-scatter")
    gather_class: tuple = ("all-gather",)

    @classmethod
    def for_phase(cls, phase: str, params: PyTree,
                  n_metric_reductions: int = 2) -> "CollectiveBudget":
        """Derive the budget from the analytic comm model for a live pytree.

        ``params``: the global buffer pytree (x0) the phase moves —
        ``n_param_leaves`` and the payload bytes come from it (reductions
        run in the f32 momentum dtype, so the payload floor is 4 B/elem).
        """
        from benchmarks.comm import phase_collective_budget

        import jax

        leaves = jax.tree.leaves(params)
        payload = sum(l.size * max(4, getattr(l.dtype, "itemsize", 4))
                      for l in leaves)
        raw = phase_collective_budget(
            phase, n_param_leaves=len(leaves), payload_bytes=payload,
            n_metric_reductions=n_metric_reductions)
        return cls(
            phase=raw["phase"],
            max_reduce_ops=raw["max_reduce_ops"],
            max_gather_ops=raw["max_gather_ops"],
            max_reduce_bytes=raw["max_reduce_bytes"],
            max_gather_bytes=raw["max_gather_bytes"],
            reduce_class=tuple(raw["reduce_class"]),
            gather_class=tuple(raw["gather_class"]),
        )


@dataclasses.dataclass
class AuditReport:
    name: str
    budget: CollectiveBudget
    ops: list
    violations: list

    @property
    def passed(self) -> bool:
        return not self.violations

    @property
    def counts(self) -> dict:
        c: dict = {}
        for op in self.ops:
            c[op.kind] = c.get(op.kind, 0) + 1
        return c

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "phase": self.budget.phase,
            "passed": self.passed,
            "counts": self.counts,
            "reduce_bytes": sum(o.bytes for o in self.ops
                                if o.kind in self.budget.reduce_class),
            "gather_bytes": sum(o.bytes for o in self.ops
                                if o.kind in self.budget.gather_class),
            "budget": dataclasses.asdict(self.budget),
            "violations": list(self.violations),
            "ops": [dataclasses.asdict(o) for o in self.ops],
        }


def audit_text(hlo_text: str, budget: CollectiveBudget,
               name: str = "step") -> AuditReport:
    """Check compiled HLO text against a budget; returns the full report."""
    ops = parse_collectives(hlo_text)
    viol = []
    reduce_ops = [o for o in ops if o.kind in budget.reduce_class]
    gather_ops = [o for o in ops if o.kind in budget.gather_class]
    allowed = set(budget.reduce_class) | set(budget.gather_class)
    for o in ops:
        if o.kind not in allowed:
            viol.append(
                f"forbidden collective {o.kind} {o.shape} at HLO line {o.line}")
    if len(reduce_ops) > budget.max_reduce_ops:
        viol.append(
            f"{len(reduce_ops)} reduction ops ({'/'.join(budget.reduce_class)})"
            f" exceed the budget of {budget.max_reduce_ops}"
            " — a stray reduction beyond the phase's "
            f"{'single logical round' if budget.max_reduce_ops else 'zero rounds'}")
    if len(gather_ops) > budget.max_gather_ops:
        viol.append(
            f"{len(gather_ops)} gather ops exceed the budget of "
            f"{budget.max_gather_ops}")
    rbytes = sum(o.bytes for o in reduce_ops)
    gbytes = sum(o.bytes for o in gather_ops)
    if rbytes > budget.max_reduce_bytes:
        viol.append(
            f"reduction payload {rbytes} B exceeds the budget of "
            f"{budget.max_reduce_bytes} B (analytic model x slack)")
    if gbytes > budget.max_gather_bytes:
        viol.append(
            f"gather payload {gbytes} B exceeds the budget of "
            f"{budget.max_gather_bytes} B")
    return AuditReport(name=name, budget=budget, ops=ops, violations=viol)


def audit_jitted(fn, args: Sequence, budget: CollectiveBudget,
                 name: str = "step") -> AuditReport:
    """Lower ``jax.jit(fn)(*args)`` to compiled HLO and audit it."""
    import jax

    text = jax.jit(fn).lower(*args).compile().as_text()
    return audit_text(text, budget, name=name)


# ---------------------------------------------------------------------------
# The standard audit matrix (the CI gate)
# ---------------------------------------------------------------------------

def standard_audit(n_workers: int = 4, tau: int = 2,
                   self_test: bool = False) -> list[AuditReport]:
    """Audit the dense, device-parallel, and ZeRO-sharded outer steps plus
    the bare local phase of a nano model on the host training mesh.

    ``self_test`` appends a deliberately-planted extra all-reduce variant
    that MUST fail — proof the auditor is not vacuously passing.

    Meaningful only on a multi-device host (the degenerate worker=1 mesh
    compiles no collectives at all); the CLI forces the device count before
    jax is imported and flags a degenerate run in the report.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from benchmarks.tables import NANO
    from repro.core import (DSMConfig, constant, dsm_init, get_base_optimizer,
                            make_dsm_step, make_local_phase)
    from repro.data.pipeline import MarkovCorpus, dsm_batches
    from repro.distributed.compat import shard_map
    from repro.launch.mesh import host_training_mesh
    from repro.models import transformer as T

    def loss(p, mb):
        return T.loss_fn(p, mb, NANO, remat=False)

    base = get_base_optimizer("adamw")
    sched = constant(2e-2)
    batch = jax.tree.map(jnp.asarray, next(dsm_batches(
        MarkovCorpus(NANO.vocab_size, seed=1), n_workers, tau, 1, 2, 32,
        seed=3)))
    params = T.init_params(jax.random.PRNGKey(3), NANO)
    mesh = host_training_mesh(n_workers)

    variants = [
        # name, device_parallel_local, zero_sharded, mesh, phase
        ("dense", False, False, None, "local"),
        ("device_parallel", True, False, mesh, "global_dense"),
        ("zero_sharded", True, True, mesh, "global_zero"),
    ]
    reports = []
    for name, dp, zs, m, phase in variants:
        cfg = DSMConfig(tau=tau, zero_sharded=zs, device_parallel_local=dp)
        step = make_dsm_step(loss, base, cfg, sched, mesh=m)
        state = dsm_init(params, base, n_workers, mesh=m, global_sharded=zs)
        budget = CollectiveBudget.for_phase(phase, state.x0)
        reports.append(audit_jitted(step, (state, batch), budget, name=name))

    # the bare local phase: ZERO collectives by construction
    lp = make_local_phase(loss, base, accum=True, device_parallel=True,
                          mesh=mesh)
    state = dsm_init(params, base, n_workers, mesh=mesh, global_sharded=False)
    budget = CollectiveBudget.for_phase("local", state.x0)
    reports.append(audit_jitted(
        lp, (state.params, state.base_state, batch, jnp.float32(2e-2),
             jnp.int32(0)),
        budget, name="local_phase"))

    # the TRAINER-built instrumented step (build_algorithm wires the obs
    # metric pack into the outer step): must fit the SAME global_zero
    # budget as the bare zero_sharded step — the proof that observability
    # added no collectives beyond the audited allowance
    from repro.train.trainer import TrainSettings, build_algorithm

    ts = TrainSettings(algorithm="dsm", n_workers=n_workers, tau=tau,
                       steps=4, zero_sharded=True,
                       device_parallel_local=True)
    t_init, t_step, _, _ = build_algorithm(loss, ts, mesh=mesh)
    t_state = t_init(params, n_workers)

    def instrumented(st, b):
        return t_step(st, b, None, None)

    budget = CollectiveBudget.for_phase("global_zero", t_state.x0)
    reports.append(audit_jitted(instrumented, (t_state, batch), budget,
                                name="trainer_instrumented_zero"))

    if self_test:
        # plant one extra all-reduce of every param leaf on top of the
        # device-parallel step: the budget MUST flag it
        cfg = DSMConfig(tau=tau, device_parallel_local=True)
        step = make_dsm_step(loss, base, cfg, sched, mesh=mesh)
        state = dsm_init(params, base, n_workers, mesh=mesh,
                         global_sharded=False)

        def psum_workers(tree):
            return shard_map(
                lambda t: jax.tree.map(
                    lambda x: jax.lax.psum(x, "worker"), t),
                mesh=mesh, in_specs=P("worker"), out_specs=P(),
                check_rep=False)(tree)

        def planted(state, batch):
            new_state, metrics = step(state, batch)
            extra = psum_workers(new_state.params)
            bias = sum(jnp.sum(l) * 0.0 for l in jax.tree.leaves(extra))
            return new_state, dict(metrics, planted=metrics["loss"] + bias)

        budget = CollectiveBudget.for_phase("global_dense", state.x0)
        reports.append(audit_jitted(planted, (state, batch), budget,
                                    name="self_test_planted_all_reduce"))
    return reports
