"""Custom AST lint: the jax bug classes generic linters cannot see.

Rules (catalog in docs/analysis.md; suppress a line with ``# noqa: RPR0xx``
or a bare ``# noqa``):

  RPR001  reused jax.random key — the same key Name consumed by two
          ``jax.random`` primitives without an intervening reassignment
          (``split``/re-bind).  ``fold_in(key, data)`` is exempt: deriving
          many keys from one root with distinct fold data is the sanctioned
          idiom.  This is the bug class that silently breaks bit-exact
          resume: two sites drawing identical bits.
  RPR002  host sync inside jit-reachable code — ``float()`` / ``int()`` /
          ``bool()`` on non-literals, ``.item()`` / ``.tolist()``,
          ``np.asarray`` / ``np.array``, ``jax.device_get`` inside a
          function reachable from a ``jax.jit`` / ``shard_map`` region of
          the same module.  Inside jit these either fail on tracers or,
          worse, silently force a device round-trip per call when the
          region falls back to eager.
  RPR003  Python ``if`` / ``while`` on a traced value inside jit-reachable
          code — the test expression contains a jnp/jax.lax call (or a
          local assigned from one): a TracerBoolConversionError at best,
          a silently specialized branch at worst.
  RPR004  mutable default argument — ``[]`` / ``{}`` / ``set()`` defaults
          on function parameters or dataclass fields (config dataclasses
          are the motivating case: a shared mutable default aliases state
          across configs).

The checker is intentionally module-local and conservative: jit roots are
functions named in ``jax.jit(...)`` / ``shard_map(...)`` calls or carrying
``@jit`` / ``@partial(jax.jit, ...)`` decorators, and reachability follows
any Name reference from those roots to other functions defined in the same
module (callbacks included).  No jax import — pure ast.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable, Optional

RULES = {
    "RPR001": "reused jax.random key (no split between consumers)",
    "RPR002": "host sync inside jit-reachable code",
    "RPR003": "Python control flow on a traced value inside jit-reachable code",
    "RPR004": "mutable default argument",
}

# jax.random attributes that do NOT consume their key argument's uniqueness
_NON_CONSUMING = {"PRNGKey", "key", "fold_in", "key_data", "wrap_key_data",
                  "key_impl", "clone"}

_HOST_SYNC_METHODS = {"item", "tolist"}
_HOST_SYNC_BUILTINS = {"float", "int", "bool"}
_NP_SYNC_FUNCS = {"asarray", "array"}

_JIT_WRAPPERS = {"jit", "shard_map", "pmap"}
_TRACED_FN_ROOTS = {"jnp", "lax"}  # jnp.*, jax.lax.*, lax.* calls yield tracers


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _attr_chain(node: ast.AST) -> list[str]:
    """['jax', 'random', 'split'] for jax.random.split; [] if not a chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def _is_jax_random_call(call: ast.Call) -> Optional[str]:
    """The jax.random function name if this is a jax.random.<fn> call."""
    chain = _attr_chain(call.func)
    if len(chain) >= 2 and chain[-2] == "random" and chain[0] in (
            "jax", "jrandom", "random"):
        return chain[-1]
    if len(chain) == 2 and chain[0] in ("jrandom", "jr"):
        return chain[-1]
    return None


def _call_key_arg(call: ast.Call) -> Optional[str]:
    """The Name passed as the key (first positional or ``key=``), if any."""
    if call.args and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    for kw in call.keywords:
        if kw.arg == "key" and isinstance(kw.value, ast.Name):
            return kw.value.id
    return None


class _FunctionIndex(ast.NodeVisitor):
    """Module pass 1: every function def + the jit/shard_map root set."""

    def __init__(self):
        self.defs: dict[str, ast.AST] = {}
        self.roots: set[str] = set()

    def _remember(self, node):
        # innermost name wins is fine for our conservative purposes
        self.defs.setdefault(node.name, node)

    def visit_FunctionDef(self, node):
        self._remember(node)
        for dec in node.decorator_list:
            if self._is_jit_expr(dec):
                self.roots.add(node.name)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node):
        chain = _attr_chain(node.func)
        if chain and chain[-1] in _JIT_WRAPPERS:
            for arg in node.args[:1]:
                if isinstance(arg, ast.Name):
                    self.roots.add(arg.id)
                elif isinstance(arg, (ast.FunctionDef, ast.Lambda)):
                    pass  # handled by the reachability walk on the parent
        self.generic_visit(node)

    @staticmethod
    def _is_jit_expr(dec: ast.AST) -> bool:
        chain = _attr_chain(dec)
        if chain and chain[-1] in _JIT_WRAPPERS:
            return True
        if isinstance(dec, ast.Call):
            chain = _attr_chain(dec.func)
            if chain and chain[-1] in _JIT_WRAPPERS:
                return True
            if chain and chain[-1] == "partial" and dec.args:
                inner = _attr_chain(dec.args[0])
                if inner and inner[-1] in _JIT_WRAPPERS:
                    return True
        return False


def _reachable_functions(tree: ast.Module) -> set[ast.AST]:
    """Function nodes reachable from the module's jit/shard_map roots."""
    index = _FunctionIndex()
    index.visit(tree)
    seen: set[str] = set()
    work = [n for n in index.roots if n in index.defs]
    reachable: set[ast.AST] = set()
    while work:
        name = work.pop()
        if name in seen:
            continue
        seen.add(name)
        fn = index.defs[name]
        reachable.add(fn)
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                reachable.add(node)  # nested defs inherit reachability
            if isinstance(node, ast.Name) and node.id in index.defs:
                work.append(node.id)
    return reachable


def _terminates(body: list) -> bool:
    """True if a statement list cannot fall through to the next statement."""
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))


def _is_mutable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("list", "dict", "set")
    return False


def _is_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        chain = _attr_chain(dec if not isinstance(dec, ast.Call) else dec.func)
        if chain and chain[-1] == "dataclass":
            return True
    return False


class _Linter:
    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.findings: list[Finding] = []

    # -- suppression ------------------------------------------------------
    def _suppressed(self, line: int, rule: str) -> bool:
        if not 1 <= line <= len(self.lines):
            return False
        text = self.lines[line - 1]
        if "# noqa" not in text:
            return False
        tail = text.split("# noqa", 1)[1]
        codes = tail.lstrip(": ").split()
        return not codes or rule in {c.strip(",") for c in codes}

    def _add(self, node: ast.AST, rule: str, message: str):
        if not self._suppressed(node.lineno, rule):
            self.findings.append(Finding(
                path=self.path, line=node.lineno, col=node.col_offset,
                rule=rule, message=message))

    # -- driver -----------------------------------------------------------
    def run(self) -> list[Finding]:
        try:
            tree = ast.parse(self.source, filename=self.path)
        except SyntaxError as e:
            self.findings.append(Finding(
                path=self.path, line=e.lineno or 1, col=e.offset or 0,
                rule="RPR000", message=f"syntax error: {e.msg}"))
            return self.findings
        reachable = _reachable_functions(tree)
        self._check_key_reuse(tree)
        self._check_mutable_defaults(tree)
        for fn in reachable:
            self._check_host_sync(fn)
            self._check_traced_branch(fn)
        return self.findings

    # -- RPR001 -----------------------------------------------------------
    def _check_key_reuse(self, tree: ast.Module):
        for fn in [n for n in ast.walk(tree)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
            self._key_reuse_in_scope(fn)

    def _key_reuse_in_scope(self, fn: ast.AST):
        # Abstract interpretation in SOURCE order: a consuming use marks the
        # name, a rebinding clears it, a second consuming use while marked
        # fires.  If-branches fork from a snapshot and merge by union; loop
        # bodies run twice so a loop-invariant key consumed each iteration
        # (same bits every pass) is caught on the simulated second pass.
        consumed: dict[str, int] = {}

        def clear(target: ast.AST):
            for n in ast.walk(target):
                if isinstance(n, ast.Name):
                    consumed.pop(n.id, None)

        def eval_expr(expr: Optional[ast.AST]):
            if expr is None:
                return
            deferred: set[int] = set()  # nodes inside lambdas: deferred scope
            for node in ast.walk(expr):
                if isinstance(node, ast.Lambda):
                    for sub in ast.walk(node):
                        if sub is not node:
                            deferred.add(id(sub))
            for node in ast.walk(expr):
                if id(node) in deferred or not isinstance(node, ast.Call):
                    continue
                rf = _is_jax_random_call(node)
                if rf is None or rf in _NON_CONSUMING:
                    continue
                key = _call_key_arg(node)
                if key is None:
                    continue
                if key in consumed:
                    self._add(
                        node, "RPR001",
                        f"key {key!r} already consumed by jax.random at line "
                        f"{consumed[key]}; split it (or fold_in) before "
                        "drawing again — identical bits break bit-exact "
                        "resume")
                else:
                    consumed[key] = node.lineno

        def run_body(stmts):
            for stmt in stmts:
                run_stmt(stmt)

        def run_stmt(stmt: ast.stmt):
            nonlocal consumed
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                return  # nested scopes are linted on their own
            if isinstance(stmt, ast.Assign):
                eval_expr(stmt.value)
                for t in stmt.targets:
                    clear(t)
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                eval_expr(stmt.value)
                clear(stmt.target)
            elif isinstance(stmt, (ast.Expr, ast.Return)):
                eval_expr(stmt.value)
            elif isinstance(stmt, ast.If):
                eval_expr(stmt.test)
                snapshot = dict(consumed)
                run_body(stmt.body)
                # a branch that cannot fall through (return/raise/...) does
                # not contribute its consumptions to the merged state
                after_then = snapshot if _terminates(stmt.body) else consumed
                consumed = dict(snapshot)
                run_body(stmt.orelse)
                if _terminates(stmt.orelse):
                    consumed = dict(after_then)
                else:
                    consumed = {**after_then, **consumed}
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                eval_expr(stmt.iter)
                for _ in range(2):  # second pass models the next iteration
                    clear(stmt.target)
                    run_body(stmt.body)
                run_body(stmt.orelse)
            elif isinstance(stmt, ast.While):
                for _ in range(2):
                    eval_expr(stmt.test)
                    run_body(stmt.body)
                run_body(stmt.orelse)
            elif isinstance(stmt, ast.With):
                for item in stmt.items:
                    eval_expr(item.context_expr)
                    if item.optional_vars is not None:
                        clear(item.optional_vars)
                run_body(stmt.body)
            elif isinstance(stmt, ast.Try):
                run_body(stmt.body)
                for handler in stmt.handlers:
                    run_body(handler.body)
                run_body(stmt.orelse)
                run_body(stmt.finalbody)
            else:
                # raise/assert/delete/global/... — evaluate any embedded
                # expressions conservatively
                for child in ast.iter_child_nodes(stmt):
                    if isinstance(child, ast.expr):
                        eval_expr(child)

        run_body(getattr(fn, "body", []))

    # -- RPR002 -----------------------------------------------------------
    def _check_host_sync(self, fn: ast.AST):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if (isinstance(node.func, ast.Name)
                    and node.func.id in _HOST_SYNC_BUILTINS
                    and node.args
                    and not isinstance(node.args[0], ast.Constant)):
                self._add(node, "RPR002",
                          f"{node.func.id}() forces a host sync inside "
                          "jit-reachable code (use jnp casts on device)")
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _HOST_SYNC_METHODS):
                self._add(node, "RPR002",
                          f".{node.func.attr}() forces a host sync inside "
                          "jit-reachable code")
            elif (len(chain) == 2 and chain[0] in ("np", "numpy")
                    and chain[1] in _NP_SYNC_FUNCS):
                self._add(node, "RPR002",
                          f"{'.'.join(chain)}() materializes on host inside "
                          "jit-reachable code (use jnp.asarray)")
            elif chain[-2:] == ["jax", "device_get"] or chain == ["device_get"]:
                self._add(node, "RPR002",
                          "jax.device_get() inside jit-reachable code")

    # -- RPR003 -----------------------------------------------------------
    def _traced_locals(self, fn: ast.AST) -> set[str]:
        traced: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                chain = _attr_chain(node.value.func)
                if chain and (chain[0] in _TRACED_FN_ROOTS
                              or chain[:2] == ["jax", "lax"]):
                    for t in node.targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                traced.add(n.id)
        return traced

    def _check_traced_branch(self, fn: ast.AST):
        traced = self._traced_locals(fn)

        def is_traced_expr(expr: ast.AST) -> bool:
            for n in ast.walk(expr):
                if isinstance(n, ast.Call):
                    chain = _attr_chain(n.func)
                    if chain and (chain[0] in _TRACED_FN_ROOTS
                                  or chain[:2] == ["jax", "lax"]):
                        return True
                if isinstance(n, ast.Name) and n.id in traced:
                    return True
            return False

        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)) and \
                    is_traced_expr(node.test):
                kw = "if" if isinstance(node, ast.If) else "while"
                self._add(node, "RPR003",
                          f"Python `{kw}` on a traced value inside "
                          "jit-reachable code — use jnp.where / lax.cond")

    # -- RPR004 -----------------------------------------------------------
    def _check_mutable_defaults(self, tree: ast.Module):
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                for default in list(args.defaults) + \
                        [d for d in args.kw_defaults if d is not None]:
                    if _is_mutable_default(default):
                        self._add(default, "RPR004",
                                  "mutable default argument in "
                                  f"{node.name}() — shared across calls")
            elif isinstance(node, ast.ClassDef) and _is_dataclass(node):
                for stmt in node.body:
                    if isinstance(stmt, ast.AnnAssign) and stmt.value is not None \
                            and _is_mutable_default(stmt.value):
                        self._add(stmt.value, "RPR004",
                                  "mutable default on dataclass field of "
                                  f"{node.name} — shared across instances")


def lint_source(source: str, path: str = "<string>") -> list[Finding]:
    return _Linter(path, source).run()


def lint_paths(paths: Iterable[str]) -> list[Finding]:
    """Lint every .py file under the given files/directories."""
    findings: list[Finding] = []
    files: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = [d for d in dirs if d != "__pycache__"]
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".py"))
        else:
            files.append(p)
    for f in files:
        with open(f, encoding="utf-8") as fh:
            findings.extend(lint_source(fh.read(), path=f))
    return findings
