"""Runtime sanitizers for the training hot loop (opt-in: ``--sanitize``).

Static analysis cannot see everything: a host sync smuggled in through a
library call, a shape-polymorphic step that silently recompiles every
round, a NaN that escapes the survivor mask.  These guards catch that
class at runtime, cheaply enough to run in CI:

  * ``no_implicit_host_sync()`` — ``jax.transfer_guard_device_to_host``
    around the hot loop: any implicit device->host transfer (a stray
    ``float()`` on a device array mid-loop) raises instead of silently
    blocking the device.  A no-op on the CPU backend, where device
    buffers are host buffers — armed on real accelerators.
  * ``RecompilationCounter`` — ``jax_log_compiles``-based: counts XLA
    compilations per function name while active.  The steady-state outer
    step must compile EXACTLY once; a second compile means the step is
    shape- or dtype-polymorphic round to round (the classic silent 100x
    slowdown).
  * ``debug_nans()`` — the chaos tier: with fault injection corrupting
    worker contributions, run the whole loop under ``jax_debug_nans``;
    the survivor mask must keep every jit OUTPUT finite, so a regression
    in the zero-before-sum masking trips immediately.

All three are context managers that restore prior config on exit, so
they compose with tests and nested use.
"""

from __future__ import annotations

import contextlib
import logging
import re
from typing import Iterator, Optional

import jax


class SanitizeError(RuntimeError):
    """A runtime sanitizer tripped (recompilation, host sync, NaN)."""


@contextlib.contextmanager
def no_implicit_host_sync(enabled: bool = True) -> Iterator[None]:
    """Disallow implicit device->host transfers inside the block."""
    if not enabled:
        yield
        return
    with jax.transfer_guard_device_to_host("disallow"):
        yield


@contextlib.contextmanager
def debug_nans(enabled: bool = True) -> Iterator[None]:
    """Enable ``jax_debug_nans`` inside the block (chaos-test tier)."""
    if not enabled:
        yield
        return
    prev = jax.config.jax_debug_nans
    jax.config.update("jax_debug_nans", True)
    try:
        yield
    finally:
        jax.config.update("jax_debug_nans", prev)


# "Compiling <name> with global shapes and types ..." — emitted by
# jax._src.interpreters.pxla under jax_log_compiles.
_COMPILE_RE = re.compile(r"^Compiling ([^\s]+) with")


class _CompileLogHandler(logging.Handler):
    def __init__(self, counter: "RecompilationCounter"):
        super().__init__(level=logging.DEBUG)
        self._counter = counter

    def emit(self, record: logging.LogRecord) -> None:
        try:
            m = _COMPILE_RE.match(record.getMessage())
        except Exception:
            return
        if m:
            name = m.group(1)
            self._counter.compiles[name] = \
                self._counter.compiles.get(name, 0) + 1


class RecompilationCounter:
    """Count XLA compilations per function name while active.

    >>> with RecompilationCounter() as rc:
    ...     step(state, batch); step(state, batch2)
    >>> rc.count("outer_step")
    1
    >>> rc.assert_steady_state("outer_step")   # raises after a recompile

    Based on ``jax_log_compiles`` (restored on exit).  Counting is by the
    jitted callable's ``__name__`` as it appears in the compile log.
    """

    _LOGGER = "jax._src.interpreters.pxla"

    def __init__(self):
        self.compiles: dict[str, int] = {}
        self._handler: Optional[_CompileLogHandler] = None
        self._prev_flag = None
        self._prev_level = None

    def __enter__(self) -> "RecompilationCounter":
        self._prev_flag = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        logger = logging.getLogger(self._LOGGER)
        self._prev_level = logger.level
        if logger.getEffectiveLevel() > logging.WARNING:
            logger.setLevel(logging.WARNING)
        self._handler = _CompileLogHandler(self)
        logger.addHandler(self._handler)
        return self

    def __exit__(self, *exc) -> None:
        logger = logging.getLogger(self._LOGGER)
        if self._handler is not None:
            logger.removeHandler(self._handler)
        if self._prev_level is not None:
            logger.setLevel(self._prev_level)
        jax.config.update("jax_log_compiles", self._prev_flag)

    def count(self, name: Optional[str] = None) -> int:
        """Compilations of ``name`` (substring match), or total."""
        if name is None:
            return sum(self.compiles.values())
        return sum(v for k, v in self.compiles.items() if name in k)

    def assert_steady_state(self, name: str, max_compiles: int = 1) -> None:
        """Raise SanitizeError if ``name`` compiled more than allowed."""
        n = self.count(name)
        if n > max_compiles:
            raise SanitizeError(
                f"{name!r} compiled {n} times (budget {max_compiles}): the "
                "step is shape/dtype-polymorphic round to round — every "
                "recompile stalls the hot loop (observed compiles: "
                f"{self.compiles})")
