"""Pytree checkpointing (npz-based; orbax is not installed offline).

Flattens any pytree with string-path keys; dtypes (incl. bf16) survive the
round trip via a view-as-uint16 trick, since npz has no bf16 support.

Crash safety: ``save`` writes both files to temporaries and ``os.replace``s
them into place (npz first, json last), so the json is the commit marker —
a checkpoint is *complete* iff both files exist, and a kill mid-write can
only ever leave an ignorable temp or an npz without its json.  On top of
the single-file primitives, the rotated-checkpoint manager
(``save_checkpoint`` / ``latest_checkpoint`` / ``restore_latest``) keeps a
``latest`` pointer and the last ``keep`` complete checkpoints in a
directory, which is what the trainer's ``checkpoint_every`` / ``resume``
settings drive (see docs/fault_tolerance.md).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_BF16_TAG = "__bf16__"
_CKPT_PREFIX = "ckpt_"
_LATEST = "latest"


def _path_str(path) -> str:
    parts = []
    for e in path:
        k = getattr(e, "key", None)
        if k is None:
            k = getattr(e, "idx", None)
        if k is None:
            k = getattr(e, "name", str(e))
        parts.append(str(k))
    return "/".join(parts)


def _atomic_replace(target: str, write_fn, mode: str) -> None:
    """Write via a same-directory temp file + ``os.replace`` (atomic on
    POSIX): readers never observe a torn ``target``."""
    tmp = f"{target}.tmp.{os.getpid()}"
    try:
        with open(tmp, mode) as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, target)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def save(path: str, tree: PyTree, step: int = 0,
         extra: Optional[dict] = None) -> None:
    """Atomically save ``tree`` as ``path.npz`` + ``path.json``.

    ``extra``: optional JSON-serializable metadata (e.g. loss history)
    stored in the json sidecar, readable via :func:`load_meta`.
    """
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays, meta = {}, {"step": step, "keys": []}
    if extra is not None:
        meta["extra"] = extra
    for i, (p, leaf) in enumerate(flat):
        key = f"a{i}"
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arrays[key] = arr.view(np.uint16)
            meta["keys"].append([_path_str(p), _BF16_TAG])
        else:
            arrays[key] = arr
            meta["keys"].append([_path_str(p), str(arr.dtype)])
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # npz first, json last: the json is the commit marker
    _atomic_replace(path + ".npz", lambda f: np.savez(f, **arrays), "wb")
    _atomic_replace(path + ".json", lambda f: json.dump(meta, f), "w")


def is_complete(path: str) -> bool:
    return os.path.exists(path + ".npz") and os.path.exists(path + ".json")


def load_meta(path: str) -> dict:
    with open(path + ".json") as f:
        return json.load(f)


def restore(path: str, like: PyTree) -> tuple[PyTree, int]:
    """Restore into the structure of ``like`` (shape- AND dtype-checked:
    a f32/i32 layout drift raises instead of silently casting)."""
    meta = load_meta(path)
    flat, treedef = jax.tree_util.tree_flatten(like)
    flat_paths = [
        _path_str(p) for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    saved = {k: i for i, (k, _) in enumerate(meta["keys"])}
    out = []
    with np.load(path + ".npz") as data:
        for leaf, pstr in zip(flat, flat_paths):
            if pstr not in saved:
                raise KeyError(f"checkpoint missing leaf {pstr}")
            i = saved[pstr]
            arr = data[f"a{i}"]
            got = meta["keys"][i][1]
            leaf_dtype = getattr(leaf, "dtype", None) or np.asarray(leaf).dtype
            want = (_BF16_TAG if leaf_dtype == jnp.bfloat16
                    else str(np.dtype(leaf_dtype)))
            if got != want:
                raise ValueError(
                    f"dtype mismatch for {pstr}: checkpoint has {got}, "
                    f"expected {want}")
            if got == _BF16_TAG:
                arr = arr.view(jnp.bfloat16)
            if tuple(arr.shape) != tuple(np.shape(leaf)):
                raise ValueError(
                    f"shape mismatch for {pstr}: {arr.shape} vs {np.shape(leaf)}")
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), meta["step"]


# ---------------------------------------------------------------------------
# Rotated checkpoint directory: ckpt_<step> files, a `latest` pointer, and
# retention of the last `keep` complete checkpoints.
# ---------------------------------------------------------------------------

def step_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"{_CKPT_PREFIX}{step:08d}")


def list_checkpoints(directory: str) -> list[tuple[int, str]]:
    """Sorted ``(step, base_path)`` for every COMPLETE checkpoint."""
    out = []
    for j in glob.glob(os.path.join(directory, f"{_CKPT_PREFIX}*.json")):
        base = j[: -len(".json")]
        if not os.path.exists(base + ".npz"):
            continue  # torn write: npz landed, json (commit marker) did not
        try:
            step = int(os.path.basename(base)[len(_CKPT_PREFIX):])
        except ValueError:
            continue
        out.append((step, base))
    return sorted(out)


def latest_checkpoint(directory: str) -> Optional[str]:
    """Base path of the newest complete checkpoint (``latest`` pointer with
    a scan fallback for a stale/missing pointer), or None."""
    ptr = os.path.join(directory, _LATEST)
    if os.path.exists(ptr):
        with open(ptr) as f:
            name = f.read().strip()
        base = os.path.join(directory, name)
        if name and is_complete(base):
            return base
    cks = list_checkpoints(directory)
    return cks[-1][1] if cks else None


def save_checkpoint(directory: str, tree: PyTree, step: int, keep: int = 3,
                    extra: Optional[dict] = None) -> str:
    """Atomic rotated save: write ``ckpt_<step>``, repoint ``latest``, prune
    all but the newest ``keep`` complete checkpoints.  Returns the base path."""
    base = step_path(directory, step)
    save(base, tree, step=step, extra=extra)
    _atomic_replace(os.path.join(directory, _LATEST),
                    lambda f: f.write(os.path.basename(base)), "w")
    if keep and keep > 0:
        for _, old in list_checkpoints(directory)[:-keep]:
            for suffix in (".npz", ".json"):
                try:
                    os.remove(old + suffix)
                except OSError:
                    pass
    return base


def restore_latest(directory: str, like: PyTree
                   ) -> Optional[tuple[PyTree, int, dict]]:
    """Restore the newest complete checkpoint: ``(tree, step, extra)``, or
    None when the directory holds no complete checkpoint."""
    base = latest_checkpoint(directory)
    if base is None:
        return None
    tree, step = restore(base, like)
    return tree, step, load_meta(base).get("extra") or {}
