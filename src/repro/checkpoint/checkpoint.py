"""Pytree checkpointing (npz-based; orbax is not installed offline).

Flattens any pytree with string-path keys; dtypes (incl. bf16) survive the
round trip via a view-as-uint16 trick, since npz has no bf16 support.
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_BF16_TAG = "__bf16__"


def _path_str(path) -> str:
    parts = []
    for e in path:
        k = getattr(e, "key", None)
        if k is None:
            k = getattr(e, "idx", None)
        if k is None:
            k = getattr(e, "name", str(e))
        parts.append(str(k))
    return "/".join(parts)


def save(path: str, tree: PyTree, step: int = 0) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays, meta = {}, {"step": step, "keys": []}
    for i, (p, leaf) in enumerate(flat):
        key = f"a{i}"
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arrays[key] = arr.view(np.uint16)
            meta["keys"].append([_path_str(p), _BF16_TAG])
        else:
            arrays[key] = arr
            meta["keys"].append([_path_str(p), str(arr.dtype)])
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def restore(path: str, like: PyTree) -> tuple[PyTree, int]:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    data = np.load(path + ".npz")
    meta = json.load(open(path + ".json"))
    flat, treedef = jax.tree_util.tree_flatten(like)
    flat_paths = [
        _path_str(p) for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    saved = {k: i for i, (k, _) in enumerate(meta["keys"])}
    out = []
    for leaf, pstr in zip(flat, flat_paths):
        if pstr not in saved:
            raise KeyError(f"checkpoint missing leaf {pstr}")
        i = saved[pstr]
        arr = data[f"a{i}"]
        if meta["keys"][i][1] == _BF16_TAG:
            arr = arr.view(jnp.bfloat16)
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {pstr}: {arr.shape} vs {np.shape(leaf)}")
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), meta["step"]
