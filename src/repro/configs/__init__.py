"""Architecture configs: 10 assigned + 3 paper GPT-2 sizes."""

from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    PAPER_ARCH_IDS,
    InputShape,
    ModelConfig,
    TopologyConfig,
    arch_supports_shape,
    load_arch,
)
