"""Config system: model architecture + training topology + input shapes.

Every assigned architecture provides a module ``repro.configs.<id>`` with
``FULL`` (the exact assigned config) and ``SMOKE`` (reduced: <=2 layers,
d_model<=512, <=4 experts) ModelConfigs plus a TopologyConfig.

Block pattern language
----------------------
``pattern`` is a repeating tuple of ``"<mixer>:<ffn>"`` strings:
  mixers: attn (full causal GQA) | swa (sliding window) | ssm (Mamba-2 SSD)
          | rglru (RG-LRU recurrent) | encattn (bidirectional)
          | xattn (causal self + cross attention, enc-dec decoder)
  ffn:    dense | moe | none
Layers = pattern tiled to n_layers; full repeats are scanned (stacked
params), the remainder is unrolled.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # "lm" | "encdec" | "vlm"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    pattern: Tuple[str, ...] = ("attn:dense",)
    window: int = 1024               # sliding-window size for "swa"
    mlp_gated: bool = True           # SwiGLU vs plain 2-matrix MLP
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_combine: str = "scatter"   # "scatter" (baseline) | "ksum" (combine-
                                   # before-reduce; see EXPERIMENTS.md SPerf)
    moe_impl: str = "ragged"       # "ragged" (ragged_dot grouped matmul) |
                                   # "dense" (masked all-experts einsum; MXU-
                                   # aligned + TP-clean for small d_ff experts)
    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    # RG-LRU
    rnn_width: Optional[int] = None  # default d_model
    # enc-dec (audio)
    enc_layers: int = 0
    enc_len: int = 1500              # whisper: 30s of audio -> 1500 frames
    # VLM
    n_patches: int = 0               # patch-embedding tokens prepended
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"          # activations
    param_dtype: str = "bfloat16"
    vocab_pad_to: int = 512          # pad vocab so the table shards evenly
    q_block: int = 1024              # blockwise-attention query tile
    attn_seq_shard: bool = False     # constrain attention activations to
                                     # sequence-sharding over the model axis
                                     # (SPerf iteration; needs mesh context)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_inner(self) -> int:        # Mamba-2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def d_rnn(self) -> int:
        return self.rnn_width if self.rnn_width is not None else self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        reps = -(-self.n_layers // len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    @property
    def n_scan_blocks(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_rem_layers(self) -> int:
        return self.n_layers % len(self.pattern)


@dataclasses.dataclass(frozen=True)
class TopologyConfig:
    """How this arch maps onto the production mesh for training."""

    n_workers_single: int = 16   # paper's n, single-pod (worker axis size)
    n_workers_multi: int = 32    # multi-pod
    grad_accum: int = 1          # microbatches per local step
    base_opt: str = "adamw"      # base optimizer for local steps
    momentum_dtype: str = "float32"  # global sign-momentum buffer dtype
    tau: int = 12                # paper's communication interval
    remat: bool = True
    remat_policy: str = "full"   # "full" | "dots" (save matmul outputs —
                                 # fewer recompute bytes, higher peak)
    attn_tp: bool = True         # False: replicate attention weights over the
                                 # model axis (kills hd-split score reshards
                                 # for small-kv archs; SPerf hillclimb)
    # which decode shapes this arch supports (DESIGN.md skips)
    supports_long_context: bool = False


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


ARCH_IDS = (
    "minitron_4b",
    "granite_moe_3b_a800m",
    "gemma3_1b",
    "granite_34b",
    "whisper_large_v3",
    "llava_next_34b",
    "deepseek_67b",
    "mamba2_780m",
    "llama4_maverick_400b_a17b",
    "recurrentgemma_2b",
)

PAPER_ARCH_IDS = ("gpt2_small", "gpt2_medium", "gpt2_large")


def load_arch(arch_id: str):
    """Returns the config module for an arch id (exposes FULL, SMOKE, TOPO)."""
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod


def arch_supports_shape(cfg: ModelConfig, topo: TopologyConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return topo.supports_long_context
    return True
