"""Architecture config: DeepSeek-67B — dense llama-arch GQA
Source: arXiv:2401.02954
"""

from repro.configs.base import ModelConfig, TopologyConfig

FULL = ModelConfig(
    name="deepseek_67b", family="lm", n_layers=95, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=22016, vocab_size=102400, head_dim=128,
    pattern=("attn:dense",), mlp_gated=True, act="silu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="deepseek_smoke", family="lm", n_layers=2, d_model=256, n_heads=8,
    n_kv_heads=2, d_ff=512, vocab_size=1000, head_dim=32,
    pattern=("attn:dense",), mlp_gated=True, act="silu", tie_embeddings=False,
    dtype="float32", param_dtype="float32",
)

TOPO = TopologyConfig(n_workers_single=2, n_workers_multi=4, grad_accum=16)
