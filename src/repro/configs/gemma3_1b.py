"""Architecture config: Gemma-3 1B — 5:1 local(sliding-window):global attention, kv=1
Source: hf:google/gemma-3-1b-pt
"""

from repro.configs.base import ModelConfig, TopologyConfig

FULL = ModelConfig(
    name="gemma3_1b", family="lm", n_layers=26, d_model=1152, n_heads=4,
    n_kv_heads=1, d_ff=6912, vocab_size=262144, head_dim=256,
    pattern=("swa:dense",) * 5 + ("attn:dense",), window=512,
    mlp_gated=True, act="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3_smoke", family="lm", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=1, d_ff=256, vocab_size=1000, head_dim=32,
    pattern=("swa:dense", "attn:dense"), window=16,
    mlp_gated=True, act="gelu", tie_embeddings=True,
    dtype="float32", param_dtype="float32",
)

TOPO = TopologyConfig(
    n_workers_single=16, n_workers_multi=32, grad_accum=1,
    supports_long_context=True,  # 5/6 layers sliding-window; global-KV @512k = 2.1GB
)
