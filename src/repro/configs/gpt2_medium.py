"""Architecture config: GPT-2 medium (paper Table 1; peak LR 0.0002)
Source: Radford et al. 2019 / paper Table 1
"""

from repro.configs.base import ModelConfig, TopologyConfig

PEAK_LR = 0.0002

FULL = ModelConfig(
    name="gpt2_medium", family="lm", n_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=4096, vocab_size=50257, head_dim=64,
    pattern=("attn:dense",), mlp_gated=False, act="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gpt2_medium_smoke", family="lm", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=512, vocab_size=1000, head_dim=32,
    pattern=("attn:dense",), mlp_gated=False, act="gelu", tie_embeddings=True,
    dtype="float32", param_dtype="float32",
)

TOPO = TopologyConfig(n_workers_single=8, n_workers_multi=16, grad_accum=1)
