"""Architecture config: Granite-34B code — dense MQA (kv=1), non-gated GELU MLP
Source: arXiv:2405.04324
"""

from repro.configs.base import ModelConfig, TopologyConfig

FULL = ModelConfig(
    name="granite_34b", family="lm", n_layers=88, d_model=6144, n_heads=48,
    n_kv_heads=1, d_ff=24576, vocab_size=49152, head_dim=128,
    pattern=("attn:dense",), mlp_gated=False, act="gelu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="granite_34b_smoke", family="lm", n_layers=2, d_model=256, n_heads=8,
    n_kv_heads=1, d_ff=512, vocab_size=1000, head_dim=32,
    pattern=("attn:dense",), mlp_gated=False, act="gelu", tie_embeddings=False,
    dtype="float32", param_dtype="float32",
)

TOPO = TopologyConfig(n_workers_single=4, n_workers_multi=8, grad_accum=8)
