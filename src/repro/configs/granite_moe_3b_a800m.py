"""Architecture config: Granite-3.0 MoE 3B-a800M — 40 experts top-8, d_ff=512/expert
Source: hf:ibm-granite/granite-3.0-1b-a400m-base (scaled per assignment)
"""

from repro.configs.base import ModelConfig, TopologyConfig

FULL = ModelConfig(
    name="granite_moe_3b_a800m", family="lm", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab_size=49155, head_dim=64,
    pattern=("attn:moe",), n_experts=40, top_k=8,
    mlp_gated=True, act="silu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite_moe_smoke", family="lm", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab_size=1000, head_dim=32,
    pattern=("attn:moe",), n_experts=4, top_k=2,
    mlp_gated=True, act="silu", tie_embeddings=True,
    dtype="float32", param_dtype="float32",
)

TOPO = TopologyConfig(n_workers_single=16, n_workers_multi=32, grad_accum=1)
