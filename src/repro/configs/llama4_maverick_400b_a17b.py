"""Architecture config: Llama-4 Maverick 400B-a17B — interleaved MoE
(128e top-1 + shared experts), early fusion
Source: hf:meta-llama/Llama-4-Scout-17B-16E (Maverick per assignment)
"""

from repro.configs.base import ModelConfig, TopologyConfig

FULL = ModelConfig(
    name="llama4_maverick_400b_a17b", family="lm", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab_size=202048, head_dim=128,
    pattern=("attn:dense", "attn:moe"), n_experts=128, top_k=1,
    n_shared_experts=1, mlp_gated=True, act="silu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="llama4_smoke", family="lm", n_layers=2, d_model=256, n_heads=8,
    n_kv_heads=2, d_ff=256, vocab_size=1000, head_dim=32,
    pattern=("attn:dense", "attn:moe"), n_experts=4, top_k=1,
    n_shared_experts=1, mlp_gated=True, act="silu", tie_embeddings=False,
    dtype="float32", param_dtype="float32",
)

# 400B params: even fully sharded over one pod, AdamW moments do not fit
# (see DESIGN.md) -> SGD base optimizer, bf16 global momentum, W=1 single-pod
# (signed-Lookahead instance of Algorithm 1) / W=2 multi-pod.
TOPO = TopologyConfig(
    n_workers_single=1, n_workers_multi=2, grad_accum=16, base_opt="sgd", momentum_dtype="bfloat16",
)
