"""Architecture config: LLaVA-NeXT-34B backbone — VLM, vision tower STUBBED (anyres patches)
Source: hf:llava-hf/llava-v1.6-mistral-7b-hf (34B per assignment)
"""

from repro.configs.base import ModelConfig, TopologyConfig

FULL = ModelConfig(
    name="llava_next_34b", family="vlm", n_layers=60, d_model=7168, n_heads=56,
    n_kv_heads=8, d_ff=20480, vocab_size=64000, head_dim=128,
    pattern=("attn:dense",), n_patches=2880,  # anyres: 5 tiles x 576
    mlp_gated=True, act="silu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="llava_smoke", family="vlm", n_layers=2, d_model=256, n_heads=8,
    n_kv_heads=2, d_ff=512, vocab_size=1000, head_dim=32,
    pattern=("attn:dense",), n_patches=16,
    mlp_gated=True, act="silu", tie_embeddings=False,
    dtype="float32", param_dtype="float32",
)

TOPO = TopologyConfig(n_workers_single=4, n_workers_multi=8, grad_accum=8)
