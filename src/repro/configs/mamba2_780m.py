"""Architecture config: Mamba-2 780M — attention-free SSD (state-space duality)
Source: arXiv:2405.21060
"""

from repro.configs.base import ModelConfig, TopologyConfig

FULL = ModelConfig(
    name="mamba2_780m", family="lm", n_layers=48, d_model=1536, n_heads=24,
    n_kv_heads=24, d_ff=0, vocab_size=50280, head_dim=64,
    pattern=("ssm:none",), ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2_smoke", family="lm", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab_size=1000, head_dim=32,
    pattern=("ssm:none",), ssm_state=16, ssm_head_dim=32, ssm_expand=2,
    tie_embeddings=True, dtype="float32", param_dtype="float32",
)

TOPO = TopologyConfig(
    n_workers_single=16, n_workers_multi=32, grad_accum=1,
    supports_long_context=True,  # O(1) recurrent state
)
