"""Architecture config: Minitron-4B (pruned Nemotron) — dense GQA
Source: arXiv:2407.14679
"""

from repro.configs.base import ModelConfig, TopologyConfig

FULL = ModelConfig(
    name="minitron_4b", family="lm", n_layers=32, d_model=3072, n_heads=24,
    n_kv_heads=8, d_ff=9216, vocab_size=256000, head_dim=128,
    pattern=("attn:dense",), mlp_gated=True, act="silu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="minitron_4b_smoke", family="lm", n_layers=2, d_model=256, n_heads=8,
    n_kv_heads=2, d_ff=512, vocab_size=1000, head_dim=32,
    pattern=("attn:dense",), mlp_gated=True, act="silu", tie_embeddings=False,
    dtype="float32", param_dtype="float32",
)

TOPO = TopologyConfig(n_workers_single=16, n_workers_multi=32, grad_accum=1)
