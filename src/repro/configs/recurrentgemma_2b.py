"""Architecture config: RecurrentGemma-2B — hybrid RG-LRU + local attention (2:1)
Source: arXiv:2402.19427
"""

from repro.configs.base import ModelConfig, TopologyConfig

FULL = ModelConfig(
    name="recurrentgemma_2b", family="lm", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab_size=256000, head_dim=256,
    pattern=("rglru:dense", "rglru:dense", "swa:dense"), window=2048,
    rnn_width=2560, mlp_gated=True, act="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma_smoke", family="lm", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=1, d_ff=256, vocab_size=1000, head_dim=32,
    pattern=("rglru:dense", "swa:dense"), window=16, rnn_width=128,
    mlp_gated=True, act="gelu", tie_embeddings=True,
    dtype="float32", param_dtype="float32",
)

TOPO = TopologyConfig(
    n_workers_single=16, n_workers_multi=32, grad_accum=1,
    supports_long_context=True,  # RG-LRU state + 2048-window attention cache
)
