"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
against these.  Audio/VLM frontends are STUBS per assignment: the specs
provide precomputed frame/patch embeddings at d_model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig, TopologyConfig
from repro.models import transformer as T

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, topo: TopologyConfig, shape: InputShape,
                      n_workers: int) -> dict:
    """Batch pytree for one DSM outer step: leaves (W, tau, accum, B_micro, ...)."""
    assert shape.kind == "train"
    W, tau, acc = n_workers, topo.tau, topo.grad_accum
    assert shape.global_batch % (W * acc) == 0, (cfg.name, shape.name, W, acc)
    bm = shape.global_batch // (W * acc)
    lead = (W, tau, acc, bm)
    act = cfg.act_dtype

    if cfg.family == "vlm":
        s_text = shape.seq_len - cfg.n_patches
        return {
            "tokens": SDS(lead + (s_text,), jnp.int32),
            "patches": SDS(lead + (cfg.n_patches, cfg.d_model), act),
        }
    if cfg.family == "encdec":
        return {
            "tokens": SDS(lead + (shape.seq_len,), jnp.int32),
            "frames": SDS(lead + (cfg.enc_len, cfg.d_model), act),
        }
    return {"tokens": SDS(lead + (shape.seq_len,), jnp.int32)}


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    assert shape.kind == "prefill"
    B, S = shape.global_batch, shape.seq_len
    act = cfg.act_dtype
    if cfg.family == "vlm":
        return {
            "tokens": SDS((B, S - cfg.n_patches), jnp.int32),
            "patches": SDS((B, cfg.n_patches, cfg.d_model), act),
        }
    if cfg.family == "encdec":
        return {
            "tokens": SDS((B, S), jnp.int32),
            "frames": SDS((B, cfg.enc_len, cfg.d_model), act),
        }
    return {"tokens": SDS((B, S), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """tokens + pos + KV cache sized to seq_len (the spec'd cache length)."""
    assert shape.kind == "decode"
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, B, S, cfg.act_dtype)
    )
    return {
        "tokens": SDS((B,), jnp.int32),
        "pos": SDS((), jnp.int32),
        "cache": cache,
    }


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (for dry-run)."""
    return jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))


def param_count(cfg: ModelConfig) -> int:
    import math

    return sum(math.prod(l.shape) for l in jax.tree.leaves(abstract_params(cfg)))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    total = param_count(cfg)
    if cfg.n_experts == 0:
        return total
    # subtract inactive expert weights
    n_moe_layers = sum(1 for k in cfg.layer_kinds() if k.endswith(":moe"))
    per_expert = (2 + int(cfg.mlp_gated)) * cfg.d_model * cfg.d_ff
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive
