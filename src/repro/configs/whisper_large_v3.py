"""Architecture config: Whisper-large-v3 backbone — enc-dec, conv/mel frontend STUBBED
Source: arXiv:2212.04356
"""

from repro.configs.base import ModelConfig, TopologyConfig

FULL = ModelConfig(
    name="whisper_large_v3", family="encdec", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, d_ff=5120, vocab_size=51866, head_dim=64,
    pattern=("xattn:dense",), enc_layers=32, enc_len=1500,
    mlp_gated=False, act="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper_smoke", family="encdec", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab_size=1000, head_dim=32,
    pattern=("xattn:dense",), enc_layers=2, enc_len=64,
    mlp_gated=False, act="gelu", tie_embeddings=True,
    dtype="float32", param_dtype="float32",
)

TOPO = TopologyConfig(n_workers_single=16, n_workers_multi=32, grad_accum=1)
