"""Core: the paper's contribution (Algorithm 1 + base optimizers + baselines)."""

from repro.core.base_opt import (
    BaseOptimizer,
    adamw,
    get_base_optimizer,
    lion,
    momentum,
    sgd,
    sophia,
)
from repro.core.dsm import (
    DSMConfig,
    DSMState,
    dsm_init,
    global_sign_momentum_step,
    make_dsm_step,
    make_local_phase,
    masked_worker_mean,
    randomized_sign_pm,
    randomized_sign_zero,
    signed_lookahead_config,
    signsgd_momentum_config,
    worker_finite_mask,
)
from repro.core.schedules import constant, cosine_with_warmup, get_schedule
