"""Pure-JAX base optimizers with an optax-like gradient-transformation API.

The paper's Algorithm 1 accepts *any* base optimizer for the local steps
(SGD, momentum SGD, AdamW, Lion, Sophia, ...).  optax is not available in
this environment, so we implement the transformations from scratch.

API
---
Each optimizer is a :class:`BaseOptimizer` with

    state = opt.init(params)
    direction, state = opt.direction(grads, state, params, step[, aux])

``direction`` returns the *update direction* ``d`` of the paper (eq. 4):
the local model update is ``x <- x - gamma * d``.  Learning-rate schedules
are applied OUTSIDE (by the local loop), matching the paper's convention of
scaling ``(x_{t,0}-x_{t,tau})`` by ``1/gamma_t``.

Note: decoupled weight decay of the base optimizer (AdamW's lambda) is
folded into the direction (``d += wd * x``), which is exactly AdamW's
``x <- x - eta*(m_hat/... + wd*x)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def _tree_zeros_like(params: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


@dataclasses.dataclass(frozen=True)
class BaseOptimizer:
    """A base optimizer: init + direction (paper's d_{t,k})."""

    name: str
    init: Callable[[PyTree], PyTree]
    direction: Callable[..., tuple[PyTree, PyTree]]


# ---------------------------------------------------------------------------
# SGD family
# ---------------------------------------------------------------------------

def sgd() -> BaseOptimizer:
    """Plain mini-batch SGD: d = g (paper eq. 5)."""

    def init(params):
        return ()

    def direction(grads, state, params, step):
        return grads, state

    return BaseOptimizer("sgd", init, direction)


def momentum(beta: float = 0.9, nesterov: bool = False) -> BaseOptimizer:
    """Polyak momentum (paper Alg. 3): m <- beta*m + g, d = m (or Nesterov)."""

    def init(params):
        return _tree_zeros_like(params)

    def direction(grads, state, params, step):
        new_m = jax.tree.map(lambda m, g: beta * m + g, state, grads)
        if nesterov:
            d = jax.tree.map(lambda m, g: beta * m + g, new_m, grads)
        else:
            d = new_m
        return d, new_m

    return BaseOptimizer("momentum", init, direction)


# ---------------------------------------------------------------------------
# AdamW (paper Alg. 2) — the paper's main base optimizer
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    m: PyTree
    v: PyTree


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    moment_dtype=jnp.float32,
) -> BaseOptimizer:
    """AdamW with decoupled weight decay.

    Defaults follow the paper's GPT-2 pre-training setup
    (beta1=0.9, beta2=0.95, lambda=0.1 as in Liu et al. 2024b).
    Moments are kept in float32 even under bf16 params (TPU practice).
    """

    def init(params):
        return AdamWState(
            m=_tree_zeros_like(params, moment_dtype),
            v=_tree_zeros_like(params, moment_dtype),
        )

    def direction(grads, state, params, step):
        count = step + 1  # 1-indexed for bias correction
        bc1 = 1.0 - b1 ** count.astype(moment_dtype)
        bc2 = 1.0 - b2 ** count.astype(moment_dtype)

        new_m = jax.tree.map(
            lambda m, g: b1 * m + (1.0 - b1) * g.astype(moment_dtype), state.m, grads
        )
        new_v = jax.tree.map(
            lambda v, g: b2 * v + (1.0 - b2) * jnp.square(g.astype(moment_dtype)),
            state.v,
            grads,
        )

        def _dir(m, v, p):
            d = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p.astype(moment_dtype)
            return d.astype(p.dtype)

        d = jax.tree.map(_dir, new_m, new_v, params)
        return d, AdamWState(new_m, new_v)

    return BaseOptimizer("adamw", init, direction)


# ---------------------------------------------------------------------------
# Lion (paper Alg. 4)
# ---------------------------------------------------------------------------

def lion(
    b1: float = 0.95,
    b2: float = 0.98,
    weight_decay: float = 0.1,
    moment_dtype=jnp.float32,
) -> BaseOptimizer:
    """Lion: d = sign(b1*m + (1-b1)*g) + wd*x ; m <- b2*m + (1-b2)*g."""

    def init(params):
        return _tree_zeros_like(params, moment_dtype)

    def direction(grads, state, params, step):
        def _dir(m, g, p):
            u = b1 * m + (1.0 - b1) * g.astype(moment_dtype)
            return (jnp.sign(u) + weight_decay * p.astype(moment_dtype)).astype(p.dtype)

        d = jax.tree.map(_dir, state, grads, params)
        new_m = jax.tree.map(
            lambda m, g: b2 * m + (1.0 - b2) * g.astype(moment_dtype), state, grads
        )
        return d, new_m

    return BaseOptimizer("lion", init, direction)


# ---------------------------------------------------------------------------
# Sophia (Liu et al. 2024b) — diagonal-Hessian clipped second-order method.
# ---------------------------------------------------------------------------

class SophiaState(NamedTuple):
    m: PyTree
    h: PyTree  # EMA of diagonal Hessian estimate


def sophia(
    b1: float = 0.96,
    b2: float = 0.99,
    rho: float = 0.04,
    weight_decay: float = 0.1,
    eps: float = 1e-12,
    moment_dtype=jnp.float32,
) -> BaseOptimizer:
    """Sophia-G with Gauss-Newton-Bartlett style diag-Hessian proxy.

    ``direction`` accepts an optional ``hess`` aux pytree (the GNB estimate,
    typically grad**2 on a resampled batch).  When absent we fall back to
    the squared gradient — the standard cheap proxy.
    Update: d = clip(m / max(rho*h, eps), -1, 1) + wd*x.
    """

    def init(params):
        return SophiaState(
            m=_tree_zeros_like(params, moment_dtype),
            h=_tree_zeros_like(params, moment_dtype),
        )

    def direction(grads, state, params, step, hess: Optional[PyTree] = None):
        new_m = jax.tree.map(
            lambda m, g: b1 * m + (1.0 - b1) * g.astype(moment_dtype), state.m, grads
        )
        hess_est = hess if hess is not None else jax.tree.map(
            lambda g: jnp.square(g.astype(moment_dtype)), grads
        )
        new_h = jax.tree.map(
            lambda h, e: b2 * h + (1.0 - b2) * e, state.h, hess_est
        )

        def _dir(m, h, p):
            d = jnp.clip(m / jnp.maximum(rho * h, eps), -1.0, 1.0)
            return (d + weight_decay * p.astype(moment_dtype)).astype(p.dtype)

        d = jax.tree.map(_dir, new_m, new_h, params)
        return d, SophiaState(new_m, new_h)

    return BaseOptimizer("sophia", init, direction)


REGISTRY: dict[str, Callable[..., BaseOptimizer]] = {
    "sgd": sgd,
    "momentum": momentum,
    "adamw": adamw,
    "lion": lion,
    "sophia": sophia,
}


def get_base_optimizer(name: str, **kwargs) -> BaseOptimizer:
    if name not in REGISTRY:
        raise ValueError(f"unknown base optimizer {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name](**kwargs)
