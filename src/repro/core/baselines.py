"""Baselines the paper compares against, on a shared local-step framework.

Implemented (all referenced in the paper):
  * SlowMo (Alg. 5, Wang et al. 2019)          -> ``slowmo``
  * signed SlowMo (§4.1 ablation)              -> ``signed_slowmo``
  * Lookahead (Zhang et al. 2019; §4.1)        -> ``lookahead``
  * Global AdamW with local steps (Alg. 7)     -> ``global_adamw``
  * Local averaging (local AdamW; App. C.2)    -> ``local_avg``
  * standalone per-step data parallel (AdamW/Sophia per-iteration
    all-reduce; the paper's upper baseline)    -> ``make_perstep_dp_step``
  * Federated MV-sto-signSGD-SIM (Alg. 6, Sun et al. 2023) ->
    ``make_mv_signsgd_step``

All local-step methods share ``make_local_step_method``: a tau-step local
phase identical to DSM's (no inter-worker collectives), followed by a
pluggable global update on ``(x0, aux, x_tau_mean, gamma)``.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.base_opt import BaseOptimizer, adamw
from repro.core.dsm import _broadcast_workers, make_local_phase, randomized_sign_pm

PyTree = Any


class LocalMethodState(NamedTuple):
    params: PyTree      # (W, *shape) per-worker
    x0: PyTree          # global model buffer
    aux: PyTree         # method-specific global state (momentum etc.)
    base_state: PyTree  # per-worker base-opt state
    t: jnp.ndarray
    inner: jnp.ndarray


def make_local_step_method(
    loss_fn: Callable,
    base_opt: BaseOptimizer,
    tau: int,
    schedule: Callable,
    init_aux: Callable[[PyTree], PyTree],
    global_update: Callable[[PyTree, PyTree, PyTree, jnp.ndarray, jnp.ndarray], tuple],
    device_parallel: bool = False,
    mesh=None,
):
    """Generic: tau local steps -> all-reduce -> ``global_update`` -> sync.

    ``global_update(x0, aux, x_tau_mean, gamma, t) -> (new_x0, new_aux)``.

    The local phase is DSM's (repro.core.dsm.make_local_phase) without the
    accumulation axis; with ``device_parallel`` + a worker mesh it runs
    shard_mapped over the worker axis, like DSM's.
    """

    local_phase = make_local_phase(
        loss_fn, base_opt, accum=False,
        device_parallel=device_parallel, mesh=mesh,
    )

    def init(params: PyTree, n_workers: int) -> LocalMethodState:
        wp = _broadcast_workers(params, n_workers)
        state = LocalMethodState(
            params=wp,
            x0=params,
            aux=init_aux(params),
            base_state=jax.vmap(base_opt.init)(wp),
            t=jnp.zeros((), jnp.int32),
            inner=jnp.zeros((), jnp.int32),
        )
        if mesh is not None:
            from repro.distributed import zero as Z

            state = state._replace(
                params=jax.tree.map(
                    lambda x: jax.device_put(x, Z.worker_sharding(mesh)),
                    state.params),
                base_state=jax.tree.map(
                    lambda x: jax.device_put(x, Z.worker_sharding(mesh))
                    if getattr(x, "ndim", 0) >= 1 else x,
                    state.base_state),
            )
        return state

    def outer_step(state: LocalMethodState, batch):
        gamma = schedule(state.t)

        params_w, base_state_w, losses = local_phase(
            state.params, state.base_state, batch, gamma, state.inner
        )

        x_tau_mean = jax.tree.map(lambda p: p.mean(axis=0), params_w)  # all-reduce
        new_x0, new_aux = global_update(state.x0, state.aux, x_tau_mean, gamma, state.t)

        n_workers = jax.tree.leaves(state.params)[0].shape[0]
        new_params = _broadcast_workers(new_x0, n_workers)
        if mesh is not None:
            from repro.distributed import zero as Z

            new_params = Z.constrain_workers(new_params, mesh)
        new_state = LocalMethodState(
            params=new_params,
            x0=new_x0,
            aux=new_aux,
            base_state=base_state_w,
            t=state.t + 1,
            inner=state.inner + tau,
        )
        # losses is (tau, W); reduce outside the collective-free local phase
        return new_state, {"loss": losses.mean(), "gamma": gamma}

    return init, outer_step


# ---------------------------------------------------------------------------
# Global updates
# ---------------------------------------------------------------------------

def _f32(x):
    return x.astype(jnp.float32)


def slowmo(loss_fn, base_opt, tau, schedule, beta: float = 0.5, alpha: float = 1.0,
           **local_kw):
    """SlowMo (Alg. 5): u <- beta*u + Delta ; x <- x0 - alpha*gamma*u."""

    def init_aux(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def global_update(x0, u, x_tau, gamma, t):
        new_u = jax.tree.map(
            lambda uu, a, b: beta * uu + (_f32(a) - _f32(b)) / gamma, u, x0, x_tau
        )
        new_x = jax.tree.map(
            lambda a, uu: (_f32(a) - alpha * gamma * uu).astype(a.dtype), x0, new_u
        )
        return new_x, new_u

    return make_local_step_method(loss_fn, base_opt, tau, schedule, init_aux,
                                  global_update, **local_kw)


def signed_slowmo(loss_fn, base_opt, tau, schedule, beta: float = 0.5, eta: float = 1.0,
                  **local_kw):
    """§4.1: u <- beta*m + (1-beta)*sign(x0-x_tau)/gamma ... wait — as printed:
    u_{t+1} = beta*m_t + ((1-beta)/gamma)*sign(x0 - x_tau); x <- x0 - eta*gamma*u.
    We implement exactly the printed form (sign taken *before* momentum)."""

    def init_aux(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def global_update(x0, m, x_tau, gamma, t):
        new_m = jax.tree.map(
            lambda mm, a, b: beta * mm
            + (1.0 - beta) / gamma * jnp.sign(_f32(a) - _f32(b)),
            m, x0, x_tau,
        )
        new_x = jax.tree.map(
            lambda a, uu: (_f32(a) - eta * gamma * uu).astype(a.dtype), x0, new_m
        )
        return new_x, new_m

    return make_local_step_method(loss_fn, base_opt, tau, schedule, init_aux,
                                  global_update, **local_kw)


def lookahead(loss_fn, base_opt, tau, schedule, beta: float = 0.2, eta: float = 1.0,
              **local_kw):
    """Lookahead (§4.1): DSM with (7) replaced by x <- x0 - eta*gamma*u (no sign)."""

    def init_aux(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def global_update(x0, m, x_tau, gamma, t):
        delta = jax.tree.map(lambda a, b: (_f32(a) - _f32(b)) / gamma, x0, x_tau)
        u = jax.tree.map(lambda mm, dd: beta * mm + (1.0 - beta) * dd, m, delta)
        new_x = jax.tree.map(
            lambda a, uu: (_f32(a) - eta * gamma * uu).astype(a.dtype), x0, u
        )
        return new_x, u

    return make_local_step_method(loss_fn, base_opt, tau, schedule, init_aux,
                                  global_update, **local_kw)


def local_avg(loss_fn, base_opt, tau, schedule, **local_kw):
    """Local AdamW / FedAvg-style: x <- mean_i x^{(i)}_{t,tau} (App. C.2)."""

    def init_aux(params):
        return ()

    def global_update(x0, aux, x_tau, gamma, t):
        return x_tau, aux

    return make_local_step_method(loss_fn, base_opt, tau, schedule, init_aux,
                                  global_update, **local_kw)


class _GlobalAdamWAux(NamedTuple):
    m: PyTree
    v: PyTree


def global_adamw(
    loss_fn, base_opt, tau, schedule,
    eta: float = 1.0, b1: float = 0.9, b2: float = 0.95,
    weight_decay: float = 0.0, eps: float = 1e-8, **local_kw,
):
    """Alg. 7: AdamW on the pseudo-gradient g = (x0 - x_tau)/gamma."""

    def init_aux(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return _GlobalAdamWAux(m=z, v=z)

    def global_update(x0, aux, x_tau, gamma, t):
        g = jax.tree.map(lambda a, b: (_f32(a) - _f32(b)) / gamma, x0, x_tau)
        new_m = jax.tree.map(lambda m, gg: b1 * m + (1 - b1) * gg, aux.m, g)
        new_v = jax.tree.map(lambda v, gg: b2 * v + (1 - b2) * gg * gg, aux.v, g)
        tc = (t + 1).astype(jnp.float32)
        bc1, bc2 = 1 - b1 ** tc, 1 - b2 ** tc

        def _upd(x, m, v):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * _f32(x)
            return (_f32(x) - eta * gamma * step).astype(x.dtype)

        return jax.tree.map(_upd, x0, new_m, new_v), _GlobalAdamWAux(new_m, new_v)

    return make_local_step_method(loss_fn, base_opt, tau, schedule, init_aux,
                                  global_update, **local_kw)


# ---------------------------------------------------------------------------
# Standalone per-step data parallel (the paper's communication-heavy upper
# baseline: all-reduce mini-batch gradients EVERY local computation round).
# ---------------------------------------------------------------------------

class PerStepDPState(NamedTuple):
    params: PyTree      # single global copy
    base_state: PyTree
    t: jnp.ndarray


def make_perstep_dp_step(loss_fn, base_opt: BaseOptimizer, tau: int, schedule):
    """tau compute rounds per call; gradient all-reduce each round.

    batch leaves are (W, tau, ...) like the local-step methods, so one call
    consumes the same tokens as one DSM outer step but communicates tau x more.
    """

    grad_fn = jax.value_and_grad(loss_fn)

    def init(params, n_workers):
        del n_workers
        return PerStepDPState(params, base_opt.init(params), jnp.zeros((), jnp.int32))

    def outer_step(state: PerStepDPState, batch):
        def one_step(carry, microbatch):
            params, base_state, k = carry
            gamma = schedule(k // tau)  # schedule indexed by outer-equivalent step
            losses, grads = jax.vmap(lambda mb: grad_fn(params, mb))(microbatch)
            g_mean = jax.tree.map(lambda g: g.mean(axis=0), grads)  # all-reduce
            d, new_bs = base_opt.direction(g_mean, base_state, params, k)
            new_p = jax.tree.map(lambda x, dd: x - gamma * dd, params, d)
            return (new_p, new_bs, k + 1), losses.mean()

        mb_scan = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), batch)
        (params, base_state, k), losses = jax.lax.scan(
            one_step, (state.params, state.base_state, state.t * tau), mb_scan
        )
        return (
            PerStepDPState(params, base_state, state.t + 1),
            {"loss": losses.mean()},
        )

    return init, outer_step


# ---------------------------------------------------------------------------
# Federated MV-sto-signSGD-SIM (Alg. 6, Sun et al. 2023)
# ---------------------------------------------------------------------------

class MVState(NamedTuple):
    x: PyTree
    x_prev: PyTree
    m: PyTree           # per-worker momentum (W, *shape)
    t: jnp.ndarray


def make_mv_signsgd_step(
    loss_fn, tau: int, gamma: float, eta: float,
    beta: float = 0.9, alpha: float = 0.5, bound: float = 1.0,
):
    """Alg. 6: local SGD from the extrapolated point, randomized-sign majority vote."""

    grad_fn = jax.value_and_grad(loss_fn)

    def init(params, n_workers):
        return MVState(
            x=params,
            x_prev=params,
            m=_broadcast_workers(
                jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params), n_workers
            ),
            t=jnp.zeros((), jnp.int32),
        )

    def outer_step(state: MVState, batch, rng: jax.Array):
        # y_t = x_t + alpha (x_t - x_{t-1})
        y = jax.tree.map(lambda a, b: a + alpha * (a - b), state.x, state.x_prev)
        n_workers = jax.tree.leaves(state.m)[0].shape[0]
        y_w = _broadcast_workers(y, n_workers)

        def one_local(carry, microbatch):
            z, k = carry

            def per_worker(p, mb):
                loss, g = grad_fn(p, mb)
                return jax.tree.map(lambda x, gg: x - gamma * gg, p, g), loss

            new_z, losses = jax.vmap(per_worker)(z, microbatch)
            return (new_z, k + 1), losses.mean()

        mb_scan = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1)[: tau], batch)
        (z_tau, _), losses = jax.lax.scan(
            one_local, (y_w, jnp.zeros((), jnp.int32)), mb_scan
        )

        # local momentum from a fresh gradient at y^{(i)} = z_tau^{(i)}
        last_mb = jax.tree.map(lambda x: x[:, -1], batch)
        _, g_last = jax.vmap(lambda p, mb: grad_fn(p, mb))(z_tau, last_mb)
        new_m = jax.tree.map(
            lambda m, g: beta * m + (1 - beta) * _f32(g), state.m, g_last
        )

        # randomized sign per worker, sum, majority vote
        leaves, treedef = jax.tree.flatten(new_m)
        keys = jax.random.split(rng, len(leaves))
        votes = [
            jax.vmap(lambda mm, kk: randomized_sign_pm(mm, kk, bound))(
                leaf, jax.random.split(key, leaf.shape[0])
            ).sum(axis=0)
            for leaf, key in zip(leaves, keys)
        ]
        vote_tree = jax.tree.unflatten(treedef, votes)
        new_x = jax.tree.map(
            lambda x, v: (_f32(x) - eta * jnp.sign(v)).astype(x.dtype),
            state.x, vote_tree,
        )
        return (
            MVState(x=new_x, x_prev=state.x, m=new_m, t=state.t + 1),
            {"loss": losses.mean()},
        )

    return init, outer_step
