"""Distributed Sign Momentum with local steps — the paper's Algorithm 1.

Structure (one *outer* step t):

  1. every worker i runs tau local steps of a base optimizer:
         x^{(i)}_{t,k+1} = x^{(i)}_{t,k} - gamma_t * d^{(i)}_{t,k}
  2. ONE all-reduce:  x_{t,tau} = mean_i x^{(i)}_{t,tau}
  3. global Lion-style sign-momentum step on the pseudo-gradient
     Delta_t = (x_{t,0} - x_{t,tau}) / gamma_t :
         u_{t+1}   = beta1 * m_t + (1-beta1) * Delta_t          (eq. 6)
         x_{t+1,0} = x_{t,0} - eta*gamma_t*(sign(u_{t+1}) + lam*x_{t,0})  (eq. 7)
         m_{t+1}   = beta2 * m_t + (1-beta2) * Delta_t          (eq. 8)
  4. broadcast x_{t+1,0} back to all workers.

Workers are represented by a leading axis ``W`` on params / optimizer state /
batches.  Under the production mesh this axis is sharded over the
``("pod","data")`` axes, so step 1 emits **no inter-worker collectives**
(everything is elementwise in W) and step 2 lowers to a single all-reduce
over (pod, data) — the tau-amortized communication the paper is about.

Instances (paper §2 "Algorithm instances"):
  * tau=1, beta1=beta2=beta, lam=0    -> signSGD with momentum (eq. 3)
  * n=1 (W=1)                         -> signed Lookahead (+ decoupled wd)

The randomized sign operators of §3.1 (eqs. 9/10) used by the theory are
provided for validation; training uses the real sign.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.base_opt import BaseOptimizer
from repro.obs import metrics as OM

PyTree = Any


# ---------------------------------------------------------------------------
# Sign operators
# ---------------------------------------------------------------------------

def sign(u: jnp.ndarray) -> jnp.ndarray:
    return jnp.sign(u)


def randomized_sign_pm(u: jnp.ndarray, key: jax.Array, bound: float) -> jnp.ndarray:
    """Eq. (9): +-sign(v_j), P[sign(v_j)] = 1/2 + |v_j|/(2B).  E[.] = v/B."""
    p_keep = 0.5 + jnp.abs(u) / (2.0 * bound)
    flip = jax.random.uniform(key, u.shape, dtype=u.dtype) < p_keep
    return jnp.where(flip, jnp.sign(u), -jnp.sign(u))


def randomized_sign_zero(u: jnp.ndarray, key: jax.Array, bound: float) -> jnp.ndarray:
    """Eq. (10): sign(v_j) w.p. |v_j|/B else 0.  E[.] = v/B."""
    keep = jax.random.uniform(key, u.shape, dtype=u.dtype) < jnp.abs(u) / bound
    return jnp.where(keep, jnp.sign(u), jnp.zeros_like(u))


SIGN_MODES = ("sign", "rand_pm", "rand_zero")


# ---------------------------------------------------------------------------
# Config / state
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DSMConfig:
    """Hyper-parameters of Algorithm 1.

    Defaults are the paper's recommended Lion parameters for the global step
    (beta1=0.95, beta2=0.98, lambda=0.1; §4 Implementations).
    """

    tau: int = 12                 # communication interval (local steps)
    global_lr: float = 1.0        # eta
    beta1: float = 0.95           # u_{t+1} interpolation (eq. 6)
    beta2: float = 0.98           # m_{t+1} interpolation (eq. 8)
    weight_decay: float = 0.1     # decoupled lambda (eq. 7)
    sign_mode: str = "sign"       # "sign" | "rand_pm" | "rand_zero"
    sign_bound: float = 1.0       # B for randomized sign (theory uses tau*R)
    zero_sharded: bool = False    # beyond-paper: ZeRO-style sharded global step
    use_kernel: bool = False      # fused Pallas kernel for the global step
    device_parallel_local: bool = False  # shard_map the local phase over "worker"
    mask_nonfinite: bool = False  # survivor-aware mean masks NaN/inf workers

    def __post_init__(self):
        if self.sign_mode not in SIGN_MODES:
            raise ValueError(f"sign_mode must be one of {SIGN_MODES}")
        if not (0.0 <= self.beta1 <= 1.0 and 0.0 <= self.beta2 <= 1.0):
            raise ValueError("momentum coefficients must lie in [0, 1]")
        if self.tau < 1:
            raise ValueError("tau must be >= 1")


class DSMState(NamedTuple):
    params: PyTree       # per-worker params, leaves (W, *shape)
    x0: PyTree           # global model buffer x_{t,0}, leaves (*shape)
    m: PyTree            # global sign momentum m_t, leaves (*shape)
    base_state: PyTree   # per-worker base-opt state, leaves (W, ...)
    t: jnp.ndarray       # outer step counter
    inner: jnp.ndarray   # total local-step counter (base-opt bias correction)


def _broadcast_workers(x0: PyTree, n_workers: int) -> PyTree:
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_workers,) + x.shape), x0
    )


# ---------------------------------------------------------------------------
# Survivor-aware aggregation (robustness layer; see docs/fault_tolerance.md).
# Line 7's worker mean becomes a mask-weighted mean: dropped workers are
# excluded by the caller-supplied survivor mask, NaN/inf-corrupted
# contributions are detected on device and masked, and a round with zero
# usable contributions leaves x0 / m bit-untouched (skip-round semantics).
# Everything is elementwise in W, so the same code runs vmapped, under the
# shard_map local phase, and inside the ZeRO-sharded global step.
# ---------------------------------------------------------------------------

def worker_finite_mask(params_w: PyTree) -> jnp.ndarray:
    """``(W,)`` bool: worker i's contribution is finite in EVERY leaf."""
    leaves = [l for l in jax.tree.leaves(params_w)
              if jnp.issubdtype(l.dtype, jnp.floating)]
    n_workers = jax.tree.leaves(params_w)[0].shape[0]
    ok = jnp.ones((n_workers,), bool)
    for l in leaves:
        ok = ok & jnp.isfinite(l).reshape(l.shape[0], -1).all(axis=1)
    return ok


def masked_worker_mean(params_w: PyTree, weights: jnp.ndarray) -> PyTree:
    """Weighted worker mean; zero-weight workers are fully zeroed BEFORE the
    sum so their NaNs cannot propagate (NaN * 0 == NaN).  An all-zero weight
    vector yields 0 (the caller must apply skip-round semantics)."""
    wsum = jnp.maximum(weights.astype(jnp.float32).sum(), 1.0)

    def leaf(p):
        w = weights.astype(p.dtype).reshape((p.shape[0],) + (1,) * (p.ndim - 1))
        contrib = jnp.where(w > 0, p, jnp.zeros((), p.dtype))
        return (w * contrib).sum(axis=0) / wsum.astype(p.dtype)

    return jax.tree.map(leaf, params_w)


def _contribution_weights(contrib: PyTree, cfg: "DSMConfig",
                          faults) -> Optional[jnp.ndarray]:
    """(W,) f32 weights combining the announced survivor mask (dropouts)
    with on-device finiteness detection, or None for the dense fast path."""
    weights = None
    if faults is not None:
        weights = faults.survivors.astype(jnp.float32)
    if cfg.mask_nonfinite or faults is not None:
        finite = worker_finite_mask(contrib).astype(jnp.float32)
        weights = finite if weights is None else weights * finite
    return weights


def dsm_init(
    params: PyTree,
    base_opt: BaseOptimizer,
    n_workers: int,
    momentum_dtype=jnp.float32,
    mesh=None,
    global_sharded: bool = True,
) -> DSMState:
    """Initialize Algorithm 1 state from a single (global) param pytree.

    With ``mesh`` (a ``("worker", "zero", "model")`` training mesh) the
    per-worker params / base state are sharded over the worker axis, and —
    when ``global_sharded`` — x0 / m are laid out for the ZeRO-sharded
    global step (sharded over the flattened (worker, zero) ranks).  A
    device-parallel local phase without ``zero_sharded`` keeps x0 / m
    replicated (``global_sharded=False``).
    """
    worker_params = _broadcast_workers(params, n_workers)
    base_state = jax.vmap(base_opt.init)(worker_params)
    state = DSMState(
        params=worker_params,
        x0=params,
        m=jax.tree.map(lambda p: jnp.zeros_like(p, dtype=momentum_dtype), params),
        base_state=base_state,
        t=jnp.zeros((), jnp.int32),
        inner=jnp.zeros((), jnp.int32),
    )
    if mesh is not None:
        from repro.distributed import zero as Z

        state = Z.shard_dsm_state(state, mesh, global_sharded=global_sharded)
    return state


# ---------------------------------------------------------------------------
# Global sign-momentum step (eqs. 6-8), jnp reference path.
# The fused Pallas kernel in repro.kernels.dsm_update implements the same
# math in one HBM pass; see kernels/ref.py for the oracle == this function.
# ---------------------------------------------------------------------------

def global_sign_momentum_step(
    x0: PyTree,
    m: PyTree,
    x_tau_mean: PyTree,
    gamma: jnp.ndarray,
    cfg: DSMConfig,
    rng: Optional[jax.Array] = None,
) -> tuple[PyTree, PyTree]:
    """Apply eqs. (6)-(8) leafwise; returns (x_{t+1,0}, m_{t+1})."""
    if cfg.use_kernel:
        # The fused kernel implements the deterministic sign only; the
        # randomized operators (eqs. 9/10) fall back to the jnp path rather
        # than silently applying the wrong sign.
        if cfg.sign_mode == "sign":
            from repro.kernels import ops as kernel_ops

            return kernel_ops.dsm_update_tree(
                x0, m, x_tau_mean, gamma,
                eta=cfg.global_lr, beta1=cfg.beta1, beta2=cfg.beta2,
                lam=cfg.weight_decay,
            )

    leaves, treedef = jax.tree.flatten(x0)
    if cfg.sign_mode == "sign":
        keys = [None] * len(leaves)
    else:
        keys = list(jax.random.split(rng, len(leaves)))

    new_x, new_m = [], []
    for leaf_x0, leaf_m, leaf_xt, key in zip(
        leaves, jax.tree.leaves(m), jax.tree.leaves(x_tau_mean), keys
    ):
        # compute dtype follows the momentum buffer (f32 default; bf16 opt-in
        # for very large models where f32 temporaries would not fit HBM)
        cdt = leaf_m.dtype
        g = gamma.astype(cdt) if hasattr(gamma, "astype") else jnp.asarray(gamma, cdt)
        delta = (leaf_x0.astype(cdt) - leaf_xt.astype(cdt)) / g
        u = jnp.asarray(cfg.beta1, cdt) * leaf_m + jnp.asarray(1.0 - cfg.beta1, cdt) * delta
        if cfg.sign_mode == "sign":
            s = jnp.sign(u)
        elif cfg.sign_mode == "rand_pm":
            s = randomized_sign_pm(u, key, cfg.sign_bound)
        else:
            s = randomized_sign_zero(u, key, cfg.sign_bound)
        x_new = leaf_x0.astype(cdt) - jnp.asarray(cfg.global_lr, cdt) * g * (
            s + jnp.asarray(cfg.weight_decay, cdt) * leaf_x0.astype(cdt)
        )
        m_new = jnp.asarray(cfg.beta2, cdt) * leaf_m + jnp.asarray(1.0 - cfg.beta2, cdt) * delta
        new_x.append(x_new.astype(leaf_x0.dtype))
        new_m.append(m_new.astype(leaf_m.dtype))

    return jax.tree.unflatten(treedef, new_x), jax.tree.unflatten(treedef, new_m)


# ---------------------------------------------------------------------------
# Local phase (Algorithm 1 lines 3-6), shared by DSM and the local-step
# baselines.  Two execution layouts, numerically identical:
#
#   * vmapped (default): the worker axis W lives on one device and is mapped
#     with jax.vmap — a *simulation* of n workers (replicated compute).
#   * device-parallel (``device_parallel=True`` + mesh): the same body runs
#     under shard_map with every per-worker input sharded P("worker"), so
#     each device executes only its own worker block.  The body contains no
#     psum/ppermute and never reads across the worker axis, so the compiled
#     local phase emits ZERO inter-worker collectives by construction — the
#     paper's premise that tau local steps are communication-free.
#     Per-worker losses are returned unreduced (tau, W); the caller averages
#     them *outside* the local phase, where a collective is expected anyway.
# ---------------------------------------------------------------------------

def make_local_phase(
    loss_fn: Callable[[PyTree, Any], jnp.ndarray],
    base_opt: BaseOptimizer,
    *,
    accum: bool = True,
    device_parallel: bool = False,
    mesh=None,
):
    """Build ``local_phase(params_w, base_state_w, batch, gamma, inner0) ->
    (params_w, base_state_w, losses)`` with ``losses`` shaped ``(tau, W)``.

    ``accum``: batch leaves carry a gradient-accumulation axis —
    ``(W, tau, accum, B_micro, ...)`` — consumed by an inner scan; otherwise
    leaves are ``(W, tau, B, ...)`` and each local step is one minibatch.
    """

    grad_fn = jax.value_and_grad(loss_fn)

    def local_phase_block(params_w, base_state_w, batch, gamma, inner0):
        """tau local steps over whatever worker block the caller holds."""

        def one_local_step(carry, microbatch):
            params, base_state, k = carry

            def per_worker(p, bs, mb):
                if accum:
                    # mb leaves: (accum, B_micro, ...) -> accumulate grads
                    def acc_step(carry, mbi):
                        g_sum, loss_sum = carry
                        loss, g = grad_fn(p, mbi)
                        return (
                            jax.tree.map(jnp.add, g_sum, g),
                            loss_sum + loss,
                        ), None

                    acc = jax.tree.leaves(mb)[0].shape[0]
                    g0 = jax.tree.map(lambda x: jnp.zeros_like(x), p)
                    (g_sum, loss_sum), _ = jax.lax.scan(
                        acc_step, (g0, jnp.zeros((), jnp.float32)), mb
                    )
                    grads = jax.tree.map(lambda g: g / acc, g_sum)
                    loss = loss_sum / acc
                else:
                    loss, grads = grad_fn(p, mb)
                d, new_bs = base_opt.direction(grads, bs, p, inner0 + k)
                new_p = jax.tree.map(
                    lambda x, dd: (
                        x.astype(jnp.float32) - gamma * dd.astype(jnp.float32)
                    ).astype(x.dtype),
                    p, d,
                )
                return new_p, new_bs, loss

            new_params, new_base, losses = jax.vmap(per_worker)(
                params, base_state, microbatch
            )
            return (new_params, new_base, k + 1), losses  # (W_block,)

        # scan over the tau microbatches: batch leaves (W, tau, ...) -> (tau, W, ...)
        mb_scan = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), batch)
        (params_w, base_state_w, _), losses = jax.lax.scan(
            one_local_step, (params_w, base_state_w, jnp.zeros((), jnp.int32)), mb_scan
        )
        return params_w, base_state_w, losses  # losses: (tau, W_block)

    if not device_parallel:
        return local_phase_block

    if mesh is None or "worker" not in mesh.axis_names:
        raise ValueError(
            "device_parallel local phase needs a mesh with a 'worker' axis "
            "(repro.launch.mesh.training_mesh / host_training_mesh)"
        )

    from jax.sharding import PartitionSpec as P

    from repro.distributed.compat import shard_map

    wspec = P("worker")
    n_worker_devices = dict(zip(mesh.axis_names, mesh.devices.shape))["worker"]
    sharded_block = shard_map(
        local_phase_block,
        mesh=mesh,
        in_specs=(wspec, wspec, wspec, P(), P()),
        out_specs=(wspec, wspec, P(None, "worker")),
        check_rep=False,
    )

    def local_phase(params_w, base_state_w, batch, gamma, inner0):
        n_workers = jax.tree.leaves(params_w)[0].shape[0]
        if n_workers % n_worker_devices:
            raise ValueError(
                f"n_workers={n_workers} must be a multiple of the mesh's "
                f"worker axis ({n_worker_devices}) for the device-parallel "
                "local phase"
            )
        return sharded_block(params_w, base_state_w, batch, gamma, inner0)

    return local_phase


# ---------------------------------------------------------------------------
# Outer-step factory
# ---------------------------------------------------------------------------

def make_dsm_step(
    loss_fn: Callable[[PyTree, Any], jnp.ndarray],
    base_opt: BaseOptimizer,
    cfg: DSMConfig,
    schedule: Callable[[jnp.ndarray], jnp.ndarray],
    mesh=None,
):
    """Build ``outer_step(state, batch[, rng]) -> (state, metrics)``.

    ``batch`` must have leaves shaped ``(W, tau, accum, B_micro, ...)``:
    worker axis first, one microbatch-group per local step, ``accum``
    gradient-accumulation microbatches inside each local step.
    ``loss_fn(params, microbatch)`` consumes single-worker params and one
    ``(B_micro, ...)`` microbatch.

    With ``cfg.zero_sharded`` and a ``("worker", "zero", "model")`` mesh, the
    global step runs ZeRO-sharded (repro.distributed.zero): reduce-scatter of
    x_tau, shard-local update of x0 / m, all-gather of x_{t+1,0} via the
    worker broadcast.  With ``cfg.device_parallel_local`` the tau local steps
    run under shard_map with every per-worker buffer sharded over the mesh's
    worker axis — genuinely data-parallel, zero inter-worker collectives.

    ``faults`` (optional ``repro.robustness.faults.FaultRound``) makes the
    round survivor-aware: announced dropouts are excluded from the x_tau
    mean, straggler/corrupt contributions are injected, and non-finite
    contributions are detected and masked on device.  A round with no
    usable contribution leaves x0 / m bit-untouched (workers still re-sync
    from the unchanged x0).  ``cfg.mask_nonfinite`` enables the detection
    path without injection (real-run protection).
    """

    local_phase = make_local_phase(
        loss_fn, base_opt, accum=True,
        device_parallel=cfg.device_parallel_local, mesh=mesh,
    )

    def outer_step(state: DSMState, batch, rng: Optional[jax.Array] = None,
                   faults=None):
        gamma = schedule(state.t)
        n_workers = jax.tree.leaves(state.params)[0].shape[0]

        with jax.named_scope("dsm_local_phase"):
            params_w, base_state_w, losses = local_phase(
                state.params, state.base_state, batch, gamma, state.inner
            )

        # --- fault injection + survivor weights (None -> dense fast path,
        # identical to the pre-robustness step) ---
        contrib = params_w
        if faults is not None:
            from repro.robustness.faults import apply_faults

            contrib = apply_faults(params_w, state.x0, faults)
        weights = _contribution_weights(contrib, cfg, faults)

        with jax.named_scope("dsm_global_step"):
            if cfg.zero_sharded and mesh is not None:
                # --- lines 7-10, ZeRO-sharded: reduce-scatter(x_tau) ->
                # shard-local sign momentum on each rank's 1/(W*zero) slice ---
                from repro.distributed import zero as Z

                new_x0, new_m, x_tau = Z.sharded_global_sign_momentum_step(
                    state.x0, state.m, contrib, gamma, cfg, mesh, rng,
                    weights=weights, return_x_tau=True,
                )
                # pre-update Delta/momentum stats on the sharded buffers:
                # ONE psum for the whole pack (repro.obs.metrics)
                stat = Z.sharded_stat_sums(state.x0, state.m, x_tau, gamma,
                                           cfg.beta1, mesh)
            else:
                # --- line 7: THE all-reduce over workers (once per tau local steps) ---
                if weights is None:
                    x_tau = jax.tree.map(lambda p: p.mean(axis=0), contrib)
                else:
                    x_tau = masked_worker_mean(contrib, weights)
                if mesh is not None:
                    # the worker-axis reduction already replicates its result;
                    # pin that layout so the stat sums below never re-reduce
                    from repro.distributed import zero as Z

                    x_tau = Z.constrain_replicated(x_tau, mesh)

                # --- lines 8-10: global sign momentum ---
                new_x0, new_m = global_sign_momentum_step(
                    state.x0, state.m, x_tau, gamma, cfg, rng
                )
                stat = OM.tree_stat_sums(state.x0, state.m, x_tau, gamma,
                                         cfg.beta1)

        wsum = None
        if weights is not None:
            # skip-round: zero usable contributions -> x0 / m bit-untouched
            wsum = weights.sum()
            ok = wsum > 0
            new_x0 = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                  new_x0, state.x0)
            new_m = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                 new_m, state.m)

        # --- line 11: synchronize workers (the all-gather when sharded) ---
        new_params = _broadcast_workers(new_x0, n_workers)
        if mesh is not None:
            from repro.distributed import zero as Z

            new_params = Z.constrain_workers(new_params, mesh)

        new_state = DSMState(
            params=new_params,
            x0=new_x0,
            m=new_m,
            base_state=base_state_w,
            t=state.t + 1,
            inner=state.inner + cfg.tau,
        )
        # losses is (tau, W): per-worker means happen HERE, outside the
        # collective-free local phase, as ONE stacked reduction
        loss_mean, last_loss, worker_spread = OM.loss_stats(losses)
        metrics = {"loss": loss_mean, "gamma": gamma, "last_loss": last_loss}
        metrics["pack"] = OM.finish_pack(
            loss=loss_mean, last_loss=last_loss, gamma=gamma,
            worker_spread=worker_spread, stat_sums=stat,
            n_elems=OM.n_elements(state.x0),
            survivor_frac=None if wsum is None else wsum / n_workers,
        )
        if wsum is not None:
            metrics["survivors"] = wsum
        return new_state, metrics

    return outer_step


# ---------------------------------------------------------------------------
# Convenience instances
# ---------------------------------------------------------------------------

def signsgd_momentum_config(beta: float) -> DSMConfig:
    """tau=1, beta1=beta2=beta, lam=0: exactly eq. (3) signSGD w/ momentum."""
    return DSMConfig(tau=1, beta1=beta, beta2=beta, weight_decay=0.0)


def signed_lookahead_config(tau: int, beta: float, weight_decay: float = 0.0) -> DSMConfig:
    """n=1 instance (§4.1 ablation): signed Lookahead with decoupled wd."""
    return DSMConfig(tau=tau, beta1=beta, beta2=beta, weight_decay=weight_decay)
