"""Learning-rate schedules (paper §4: cosine with 2k warmup, 0.05x floor)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(peak_lr: float):
    def sched(step):
        return jnp.asarray(peak_lr, dtype=jnp.float32)

    return sched


def cosine_with_warmup(
    peak_lr: float,
    total_steps: int,
    warmup_steps: int = 2000,
    final_frac: float = 0.05,
):
    """Linear warmup to ``peak_lr`` then cosine decay to ``final_frac * peak_lr``.

    Matches the paper's setup: 2k-step warmup, final LR = 0.05 x peak LR.
    """
    min_lr = final_frac * peak_lr

    def sched(step):
        step = jnp.asarray(step, dtype=jnp.float32)
        warm = peak_lr * (step + 1.0) / max(warmup_steps, 1)
        progress = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = min_lr + 0.5 * (peak_lr - min_lr) * (1.0 + jnp.cos(jnp.pi * progress))
        return jnp.where(step < warmup_steps, warm, cos).astype(jnp.float32)

    return sched


def get_schedule(name: str, peak_lr: float, total_steps: int = 10000, **kw):
    if name == "constant":
        return constant(peak_lr)
    if name == "cosine":
        return cosine_with_warmup(peak_lr, total_steps, **kw)
    raise ValueError(f"unknown schedule {name!r}")
