"""Data pipeline: deterministic synthetic LM corpora + per-worker sharding.

The paper pre-trains on OpenWebText; offline we provide two corpora with
real sequential structure (so optimizers separate, unlike iid noise):

  * ``MarkovCorpus`` — an order-2 token-level Markov chain with a sparse
    random transition kernel.  Entropy is controlled, loss floors are
    computable, and 100-step training curves already separate optimizers.
  * ``TextCorpus``   — byte-level corpus from any file (self-hosting: we
    ship our own source tree as the default corpus).

Batches are yielded in the DSM layout (W, tau, accum, B_micro, S):
worker i always consumes stream shard i (the paper's D_i), giving the
data-heterogeneity the theory's delta^2 term describes.
"""

from __future__ import annotations

import glob
import os
from typing import Iterator

import numpy as np


class MarkovCorpus:
    """Order-2 Markov chain over ``vocab`` tokens with ``branch`` choices."""

    def __init__(self, vocab: int, branch: int = 8, seed: int = 0):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        # transition table: (vocab, vocab) -> `branch` next tokens + probs
        self.next_tokens = rng.integers(0, vocab, size=(vocab, vocab, branch))
        p = rng.dirichlet(np.ones(branch) * 0.5, size=(vocab, vocab))
        self.next_cdf = np.cumsum(p, axis=-1)

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), dtype=np.int32)
        out[:, 0] = rng.integers(0, self.vocab, size=batch)
        out[:, 1] = rng.integers(0, self.vocab, size=batch)
        u = rng.random(size=(batch, seq))
        for t in range(2, seq):
            a, b = out[:, t - 2], out[:, t - 1]
            cdf = self.next_cdf[a, b]                       # (batch, branch)
            idx = (u[:, t : t + 1] > cdf).sum(axis=-1)
            out[:, t] = self.next_tokens[a, b, idx]
        return out


class TextCorpus:
    """Byte-level corpus over a directory of text files (vocab 256)."""

    def __init__(self, root: str = ".", pattern: str = "**/*.py", max_bytes: int = 8_000_000):
        files = sorted(glob.glob(os.path.join(root, pattern), recursive=True))
        buf = []
        total = 0
        for f in files:
            try:
                b = open(f, "rb").read()
            except OSError:
                continue
            buf.append(b)
            total += len(b)
            if total >= max_bytes:
                break
        data = b"\n".join(buf)
        if len(data) < 65536:
            raise ValueError(f"corpus too small: {len(data)} bytes from {root}/{pattern}")
        self.data = np.frombuffer(data, dtype=np.uint8).astype(np.int32)
        self.vocab = 256

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        starts = rng.integers(0, len(self.data) - seq - 1, size=batch)
        return np.stack([self.data[s : s + seq] for s in starts])


def dsm_batches(
    corpus,
    n_workers: int,
    tau: int,
    accum: int,
    b_micro: int,
    seq: int,
    seed: int = 0,
    heterogeneous: bool = True,
) -> Iterator[dict]:
    """Yield DSM outer-step batches {tokens: (W, tau, accum, B_micro, S)}.

    ``heterogeneous``: each worker draws from its own stream (paper's D_i);
    otherwise all workers share one stream (iid split).
    """
    rngs = [np.random.default_rng(seed + (i if heterogeneous else 0) * 1009 + 1)
            for i in range(n_workers)]
    while True:
        tokens = np.stack([
            corpus.sample(rngs[i], tau * accum * b_micro, seq)
            .reshape(tau, accum, b_micro, seq)
            for i in range(n_workers)
        ])
        yield {"tokens": tokens}


def eval_batch(corpus, batch: int, seq: int, seed: int = 10_000) -> dict:
    rng = np.random.default_rng(seed)
    return {"tokens": corpus.sample(rng, batch, seq)}
