"""Shims over jax API drift, so one source tree spans the CI version matrix.

``shard_map`` graduated out of ``jax.experimental`` (it is ``jax.shard_map``
on newer releases, ``jax.experimental.shard_map.shard_map`` on the minimum
pinned version).  Import it from here everywhere; the CI fast job runs both
ends of the supported range to catch the next such move before nightly does.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401  (min pin)

__all__ = ["shard_map"]
