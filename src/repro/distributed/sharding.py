"""Sharding rules: map parameter / batch / cache pytrees onto the mesh.

Training mesh axes: ``("worker", "zero", "model")``
  worker — the paper's n workers (local-step isolation; pod*data rows)
  zero   — FSDP/ZeRO shard *within* a worker (paper §2: "ZeRO-2 for local
           steps ... faster intra-node communication")
  model  — tensor parallel within a worker

Serving mesh axes: ``("data", "model")``.

Rules are name-aware (Megatron-style column/row parallel) with a generic
divisibility fallback; dims that don't divide are replicated.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# leaf-name -> which dim (from the end, ignoring stacked prefixes) is the
# model-parallel one. "col": last dim; "row": second-to-last dim.
_COL = ("wq", "wk", "wv", "w1", "w3", "in_proj", "in_x", "in_gate",
        "w_a", "w_x", "lm_head", "patch_proj", "we1", "we3")
_ROW = ("wo", "w2", "out_proj", "out", "we2")


def _model_dim(name: str, shape: tuple, i0: int, model: int) -> Optional[int]:
    nd = len(shape)
    if nd - i0 < 1:
        return None
    cands = []
    if name == "embed":
        # vocab-parallel: logits shard over V (logsumexp psum is tiny);
        # the lookup becomes masked-gather + small psum of (B,S,d).
        cands = [i0, nd - 1]
    elif name in _COL:
        cands = [nd - 1, nd - 2]
    elif name in _ROW:
        cands = [nd - 2, nd - 1]
    else:
        cands = [nd - 1, nd - 2]
    for c in cands:
        if c >= i0 and shape[c] % model == 0 and shape[c] >= model:
            return c
    return None


def _pick_dim(shape: tuple, i0: int, size: int, taken: set) -> Optional[int]:
    """Largest eligible dim divisible by ``size``."""
    best = None
    for i in range(i0, len(shape)):
        if i in taken or shape[i] % size != 0 or shape[i] < size:
            continue
        if best is None or shape[i] > shape[best]:
            best = i
    return best


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def param_pspecs(
    abstract_params: PyTree,
    *,
    model: int,
    zero: int = 1,
    worker_axis: bool = False,
    zero_axes=("zero",),
    model_axis: str = "model",
    replicate_names: tuple = (),
) -> PyTree:
    """PartitionSpecs for a parameter pytree.

    ``worker_axis``: leaves carry a leading per-worker dim -> "worker".
    ``zero_axes``: mesh axes for the FSDP dim (e.g. ("zero",) or
    ("worker","zero") for fully-sharded global buffers).
    """
    zero_total = zero

    def spec_for(path, leaf):
        shape = leaf.shape
        name = _leaf_name(path)
        spec = [None] * len(shape)
        i0 = 0
        if worker_axis:
            if len(shape) == 0:
                return P()
            spec[0] = "worker"
            i0 = 1
        # stacked-layer dim (scan) right after worker dim: leave unsharded
        path_str = "/".join(str(getattr(e, "key", e)) for e in path)
        if "blocks" in path_str and len(shape) > i0:
            i0 += 1
        taken = set()
        md = (
            _model_dim(name, shape, i0, model)
            if model > 1 and name not in replicate_names else None
        )
        if md is not None:
            spec[md] = model_axis
            taken.add(md)
        if zero_total > 1:
            zd = _pick_dim(shape, i0, zero_total, taken)
            if zd is not None:
                spec[zd] = zero_axes if len(zero_axes) > 1 else zero_axes[0]
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, abstract_params)


def train_batch_pspecs(batch: PyTree, zero: int = 1, model: int = 1) -> PyTree:
    """Batch leaves (W, tau, accum, B_micro, ...): worker on W, zero on B.

    Float leaves (stub frame/patch embeddings) also shard their trailing
    feature dim over model — they are the dominant input bytes for
    audio/VLM archs.
    """

    def spec_for(leaf):
        spec = [None] * len(leaf.shape)
        spec[0] = "worker"
        if (len(leaf.shape) > 3 and zero > 1
                and leaf.shape[3] % zero == 0 and leaf.shape[3] >= zero):
            spec[3] = "zero"
        if (model > 1 and len(leaf.shape) > 4
                and jnp.issubdtype(leaf.dtype, jnp.floating)
                and leaf.shape[-1] % model == 0 and leaf.shape[-1] >= model):
            spec[-1] = "model"
        return P(*spec)

    return jax.tree.map(spec_for, batch)


def serve_batch_pspecs(batch: PyTree, data: int, model: int) -> PyTree:
    """Prefill batch (B, S, ...): B over data (fallback: S)."""

    def spec_for(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 1 and shape[0] % data == 0 and shape[0] >= data:
            spec[0] = "data"
        elif len(shape) >= 2 and shape[1] % data == 0:
            spec[1] = "data"
        return P(*spec)

    return jax.tree.map(spec_for, batch)


def cache_pspecs(cache: PyTree, data: int, model: int, stacked_hint: bool = True) -> PyTree:
    """KV/state cache sharding: batch dim over data (fallback: seq), last
    divisible dim over model."""

    def spec_for(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        path_str = "/".join(str(getattr(e, "key", e)) for e in path)
        i0 = 1 if ("blocks" in path_str and len(shape) > 1) else 0
        taken = set()
        # data axis: prefer batch dim (i0), else next dims
        dd = None
        for i in range(i0, len(shape)):
            if shape[i] % data == 0 and shape[i] >= data:
                dd = i
                break
        if dd is not None and data > 1:
            spec[dd] = "data"
            taken.add(dd)
        # model axis: last divisible dim
        if model > 1:
            for i in range(len(shape) - 1, i0 - 1, -1):
                if i not in taken and shape[i] % model == 0 and shape[i] >= model:
                    spec[i] = "model"
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def to_named(pspecs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def replicated(tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
