"""ZeRO-sharded DSM global step (DSMConfig.zero_sharded=True).

The replicated global step keeps full copies of x0 / m on every rank and
re-does the identical sign-momentum update everywhere — O(N) HBM residency
and O(N) update traffic per rank, regardless of how many chips participate.
This module shards the *global* optimizer state over the flattened
``("worker", "zero")`` mesh axes (R = W * Z ranks) and rewrites the outer
step as

    reduce-scatter(x_tau)  ->  shard-local sign-momentum update  ->  all-gather(x_{t+1,0})

so each rank holds and updates only 1/R of x0 and m (paper §2 pairs local
steps with ZeRO-2 sharding for exactly this reason; the same split is how
SignMuon / DeMo scale their global optimizer state).

Both implementations express the reduce-scatter as the worker mean *pinned
to the shard layout* (``with_sharding_constraint`` with
``param_pspecs(..., zero_axes=("worker", "zero"))``): the SPMD partitioner
reduces over the worker axis directly into shards on collective-capable
backends, and each rank only ever consumes its own slice.  We deliberately
do NOT hand-write a ring ``psum_scatter``: an explicit ring fixes a
summation order different from the replicated baseline's, and the resulting
few-ulp difference in x_tau is amplified by 1/gamma through sign() into
training-visible divergence — whereas the partitioner-chosen reduction is
numerically identical to the replicated mean (tier-1 asserts 1e-5 agreement
over multiple outer steps; see tests/test_sharded_dsm.py).

  * jnp path: the leafwise eqs. (6)-(8) run under the shard constraint —
    elementwise, so the update itself never leaves the shard.
  * kernel path: x0 / m / x_tau are flattened into lane-aligned
    ``(rows, 128)`` slabs (rows padded to a multiple of R) sharded
    ``P(("worker", "zero"))`` on rows, and a ``shard_map`` runs the fused
    Pallas ``dsm_update_2d`` kernel on each rank's local slab.

The collective structure described above is machine-checked: the HLO
auditor (``python -m repro.analysis audit``, docs/analysis.md) compiles
this step and asserts it stays within the ``global_zero`` phase budget —
one reduction round (all-reduce/reduce-scatter equivalence class: the CPU
partitioner lowers the scattered mean as all-reduce + slice) plus one
gather round, leafwise, and nothing else.  The kernel slab path is
excluded from the default audit matrix: its per-step re-slabbing emits
collective-permute traffic that the flat-slab-storage ROADMAP item will
remove, and pinning it in a budget today would only entrench the wart.

See docs/sharding.md for the full dataflow.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compat import shard_map

from repro.distributed.sharding import param_pspecs
from repro.kernels.dsm_update import LANES, dsm_update_2d

PyTree = Any

GLOBAL_AXES = ("worker", "zero")  # flattened shard axes for x0 / m


def num_shards(mesh: Mesh) -> int:
    """R = worker * zero — the shard count for the global buffers."""
    dims = dict(zip(mesh.axis_names, mesh.devices.shape))
    return dims.get("worker", 1) * dims.get("zero", 1)


def global_buffer_pspecs(tree: PyTree, mesh: Mesh) -> PyTree:
    """Leafwise specs sharding the largest divisible dim over (worker, zero)."""
    return param_pspecs(tree, model=1, zero=num_shards(mesh),
                        zero_axes=GLOBAL_AXES)


def global_buffer_shardings(tree: PyTree, mesh: Mesh) -> PyTree:
    specs = global_buffer_pspecs(tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain_global(tree: PyTree, mesh: Mesh) -> PyTree:
    """Pin a global-buffer pytree to its (worker, zero) shard layout."""
    return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                        global_buffer_shardings(tree, mesh))


def worker_sharding(mesh: Mesh) -> NamedSharding:
    """Per-worker leaves (W, ...): shard the leading worker dim only."""
    return NamedSharding(mesh, P("worker"))


def constrain_workers(tree: PyTree, mesh: Mesh) -> PyTree:
    ws = worker_sharding(mesh)
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(x, ws)
        if getattr(x, "ndim", 0) >= 1 else x,
        tree,
    )


def shard_dsm_state(state, mesh: Mesh, global_sharded: bool = True):
    """device_put a fresh DSMState onto the mesh: per-worker params / base
    state sharded over worker; x0 / m in the ZeRO (worker, zero) layout when
    ``global_sharded``, replicated otherwise (device-parallel local phase
    with a replicated global step)."""
    ws = worker_sharding(mesh)
    rep = NamedSharding(mesh, P())

    def put_worker(x):
        return jax.device_put(x, ws if getattr(x, "ndim", 0) >= 1 else rep)

    if global_sharded:
        x0_sh = global_buffer_shardings(state.x0, mesh)
        m_sh = global_buffer_shardings(state.m, mesh)
    else:
        x0_sh = jax.tree.map(lambda _: rep, state.x0)
        m_sh = jax.tree.map(lambda _: rep, state.m)

    return type(state)(
        params=jax.tree.map(put_worker, state.params),
        x0=jax.tree.map(jax.device_put, state.x0, x0_sh),
        m=jax.tree.map(jax.device_put, state.m, m_sh),
        base_state=jax.tree.map(put_worker, state.base_state),
        t=jax.device_put(state.t, rep),
        inner=jax.device_put(state.inner, rep),
    )


# ---------------------------------------------------------------------------
# jnp / GSPMD path
# ---------------------------------------------------------------------------

def _scattered_worker_mean(params_w, mesh, weights=None):
    """x_tau = mean_i x^{(i)}_{t,tau}, reduced directly into the
    (worker, zero) shard layout — the reduce-scatter of the outer step.

    The per-worker iterates are pinned to their P("worker") layout first, so
    when the local phase ran device-parallel the partitioner consumes the
    already-worker-sharded x_tau in place (worker-axis reduction straight
    into shards) instead of gathering the W copies to every rank and
    re-scattering.

    ``weights`` (optional ``(W,)`` f32): survivor-aware masked mean — zero-
    weight (dropped / non-finite) workers are zeroed before the reduction,
    still elementwise in W, so the reduce-scatter structure is unchanged."""
    params_w = constrain_workers(params_w, mesh)
    if weights is None:
        x_tau = jax.tree.map(lambda p: p.mean(axis=0), params_w)
    else:
        from repro.core.dsm import masked_worker_mean

        x_tau = masked_worker_mean(params_w, weights)
    return constrain_global(x_tau, mesh)


def _sharded_step_jnp(x0, m, x_tau, gamma, cfg, mesh, rng):
    from repro.core.dsm import global_sign_momentum_step

    # force the jnp path: the elementwise update stays shard-local under the
    # output constraint (the kernel dispatch is handled by the slab path)
    jnp_cfg = dataclasses.replace(cfg, use_kernel=False)
    new_x0, new_m = global_sign_momentum_step(x0, m, x_tau, gamma, jnp_cfg, rng)
    return constrain_global(new_x0, mesh), constrain_global(new_m, mesh)


# ---------------------------------------------------------------------------
# kernel / shard_map path: flat slabs, psum_scatter, fused Pallas update
# ---------------------------------------------------------------------------

def _to_slab(x: jnp.ndarray, row_multiple: int) -> jnp.ndarray:
    """Flatten to a lane-aligned (rows, LANES) slab, rows % row_multiple == 0."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // LANES)
    rows = -(-rows // row_multiple) * row_multiple
    pad = rows * LANES - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, LANES)


def _from_slab(slab: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    n = like.size
    return slab.reshape(-1)[:n].reshape(like.shape).astype(like.dtype)


def dsm_update_shard(x0_l, m_l, xt_l, gamma, *, eta, beta1, beta2, lam,
                     interpret):
    """Sharded variant of the fused DSM kernel: one rank's flat slab.

    Inputs are this rank's ``(rows/R, LANES)`` slices of the slabbed
    x0 / m / x_tau; the fused Pallas kernel streams them through VMEM once,
    so the global step's HBM traffic per rank is 1/R of the replicated
    update's.
    """
    return dsm_update_2d(
        x0_l, m_l, xt_l.astype(x0_l.dtype), gamma,
        eta=eta, beta1=beta1, beta2=beta2, lam=lam, interpret=interpret,
    )


def _sharded_step_kernel(x0, m, x_tau, gamma, cfg, mesh,
                         interpret: Optional[bool] = None):
    from repro.kernels.ops import _default_interpret

    interpret = _default_interpret() if interpret is None else interpret
    R = num_shards(mesh)
    gamma32 = jnp.asarray(gamma, jnp.float32)

    x0_leaves, treedef = jax.tree.flatten(x0)
    m_leaves = jax.tree.leaves(m)
    xt_leaves = jax.tree.leaves(x_tau)

    x0_slabs = [_to_slab(l, R) for l in x0_leaves]
    m_slabs = [_to_slab(l, R) for l in m_leaves]
    xt_slabs = [
        _to_slab(l.astype(x0_l.dtype), R)
        for l, x0_l in zip(xt_leaves, x0_leaves)
    ]

    # slab rows sharded over the flattened (worker, zero) ranks: row chunk
    # w*Z + z lives on rank (w, z) for x0, m, and x_tau alike
    slab_spec = [P(GLOBAL_AXES)] * len(x0_slabs)

    def rank_fn(g, x0_ls, m_ls, xt_ls):
        outs = [
            dsm_update_shard(
                a, b, c, g, eta=cfg.global_lr, beta1=cfg.beta1,
                beta2=cfg.beta2, lam=cfg.weight_decay, interpret=interpret,
            )
            for a, b, c in zip(x0_ls, m_ls, xt_ls)
        ]
        return [o[0] for o in outs], [o[1] for o in outs]

    new_x_slabs, new_m_slabs = shard_map(
        rank_fn, mesh=mesh,
        in_specs=(P(), slab_spec, slab_spec, slab_spec),
        out_specs=(slab_spec, slab_spec),
        check_rep=False,
    )(gamma32, x0_slabs, m_slabs, xt_slabs)

    new_x0 = jax.tree.unflatten(
        treedef, [_from_slab(s, l) for s, l in zip(new_x_slabs, x0_leaves)])
    new_m = jax.tree.unflatten(
        treedef, [_from_slab(s, l) for s, l in zip(new_m_slabs, m_leaves)])
    return constrain_global(new_x0, mesh), constrain_global(new_m, mesh)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def sharded_global_sign_momentum_step(
    x0: PyTree,
    m: PyTree,
    params_w: PyTree,
    gamma: jnp.ndarray,
    cfg,
    mesh: Mesh,
    rng: Optional[jax.Array] = None,
    weights: Optional[jnp.ndarray] = None,
    return_x_tau: bool = False,
) -> tuple:
    """ZeRO-sharded eqs. (6)-(8): consumes per-worker iterates directly
    (the reduce-scatter subsumes the worker mean). Returns sharded
    (x_{t+1,0}, m_{t+1}); the caller's worker broadcast is the all-gather.

    ``weights``: optional ``(W,)`` survivor weights for the fault-tolerant
    masked mean (repro.core.dsm.masked_worker_mean); the caller applies
    skip-round semantics when all weights are zero.

    ``return_x_tau`` appends the scattered worker mean to the result so the
    caller can compute diagnostics (repro.obs) against the SAME reduction —
    the partitioner CSEs the shared subgraph, so asking for it compiles no
    second collective.

    The fused-kernel slab path supports the deterministic sign only; the
    randomized-sign modes (theory §3.1) use the jnp/GSPMD path, whose
    sampled bits are layout-independent, so sharded == replicated there too.
    """
    x_tau = _scattered_worker_mean(params_w, mesh, weights)
    if cfg.use_kernel and cfg.sign_mode == "sign":
        new_x0, new_m = _sharded_step_kernel(x0, m, x_tau, gamma, cfg, mesh)
    else:
        new_x0, new_m = _sharded_step_jnp(x0, m, x_tau, gamma, cfg, mesh, rng)
    if return_x_tau:
        return new_x0, new_m, x_tau
    return new_x0, new_m


# ---------------------------------------------------------------------------
# sharded metric-pack support (repro.obs)
# ---------------------------------------------------------------------------

def constrain_replicated(tree: PyTree, mesh: Mesh) -> PyTree:
    """Pin every leaf of a pytree to the fully-replicated layout."""
    rep = NamedSharding(mesh, P())
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(x, rep), tree)


def sharded_stat_sums(x0: PyTree, m: PyTree, x_tau: PyTree, gamma,
                      beta1: float, mesh: Mesh) -> jnp.ndarray:
    """``repro.obs.metrics`` stat sums over ZeRO-sharded global buffers,
    with ONE collective for the whole pack.

    Each rank sums its own shard slices of every leaf, stacks the partials
    into a single ``(N_STAT_SUMS,)`` vector, and ONE psum over the
    flattened (worker, zero) ranks combines them — a naive leafwise
    ``jnp.sum`` over sharded buffers would instead lower to one scalar
    all-reduce per (leaf, statistic) and blow the audited ``global_zero``
    budget.  Leaves ``param_pspecs`` left replicated (no divisible dim)
    appear on all R ranks, so their partials are pre-scaled by
    ``global_size / (local_size * R)`` — 1 for sharded leaves, 1/R for
    replicated ones — making the psum count every element exactly once.
    """
    from repro.obs import metrics as OM

    R = num_shards(mesh)
    specs = global_buffer_pspecs(x0, mesh)
    x0_leaves, _ = jax.tree.flatten(x0)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    m_leaves = jax.tree.leaves(m)
    xt_leaves = jax.tree.leaves(x_tau)
    global_sizes = [l.size for l in x0_leaves]

    def rank_fn(g, x0_ls, m_ls, xt_ls):
        tot = jnp.zeros((OM.N_STAT_SUMS,), jnp.float32)
        for gsize, x0l, ml, xtl in zip(global_sizes, x0_ls, m_ls, xt_ls):
            part = OM.stat_sums_block([x0l], [ml], [xtl], g, beta1)
            tot = tot + (gsize / (x0l.size * R)) * part
        return jax.lax.psum(tot, GLOBAL_AXES)

    leaf_specs = list(spec_leaves)
    return shard_map(
        rank_fn, mesh=mesh,
        in_specs=(P(), leaf_specs, leaf_specs, leaf_specs),
        out_specs=P(),
        check_rep=False,
    )(jnp.asarray(gamma, jnp.float32), x0_leaves, m_leaves, xt_leaves)
