"""Pallas TPU kernels for the paper's memory-bound optimizer hot-spots.

  dsm_update.py   — fused global sign-momentum step (paper eqs. 6-8)
  adamw_update.py — fused AdamW local step (paper Alg. 2)
  ops.py          — jit'd pytree wrappers (pad + lane-align + unpad)
  ref.py          — pure-jnp oracles (allclose targets for tests)

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU via interpret=True.
"""

from repro.kernels.ops import adamw_update_tree, dsm_update_tree
