"""Fused Pallas TPU kernel for one AdamW local step (paper Alg. 2).

The local AdamW step runs tau x more often than the global step and is the
memory-bound half of the base-optimizer cost: p, g (bf16) + m, v (f32) in,
p, m, v out.  Fusing moment updates + bias correction + decoupled weight
decay into one VMEM pass gives the 4-read/3-write HBM lower bound.

step (for bias correction) and gamma (LR schedule) are runtime scalars,
delivered as (1, 1) tiles; betas/eps/wd are compile-time constants.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256  # 7 live (256,128) f32 tiles = ~0.9 MiB VMEM


def _adamw_kernel(gamma_ref, step_ref, p_ref, g_ref, m_ref, v_ref,
                  p_out_ref, m_out_ref, v_out_ref, *, beta1, beta2, eps, wd):
    lr = gamma_ref[0, 0]
    c = step_ref[0, 0] + 1.0
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    mhat = m_new / (1.0 - beta1 ** c)
    vhat = v_new / (1.0 - beta2 ** c)
    p_new = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    p_out_ref[...] = p_new.astype(p_out_ref.dtype)
    m_out_ref[...] = m_new
    v_out_ref[...] = v_new


@functools.partial(
    jax.jit, static_argnames=("beta1", "beta2", "eps", "wd", "interpret")
)
def adamw_update_2d(p, g, m, v, gamma, step, *, beta1, beta2, eps, wd,
                    interpret=False):
    """p/g/m/v: (rows, 128). Returns (p_new, m_new, v_new)."""
    rows = p.shape[0]
    br = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, br),)
    gamma_arr = jnp.reshape(gamma.astype(jnp.float32), (1, 1))
    step_arr = jnp.reshape(step.astype(jnp.float32), (1, 1))

    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_adamw_kernel, beta1=beta1, beta2=beta2, eps=eps, wd=wd),
        grid=grid,
        in_specs=[scalar, scalar, tile, tile, tile, tile],
        out_specs=[tile, tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(m.shape, jnp.float32),
            jax.ShapeDtypeStruct(v.shape, jnp.float32),
        ],
        interpret=interpret,
    )(gamma_arr, step_arr, p, g, m, v)
