"""Fused Pallas TPU kernel for the DSM global sign-momentum step (eqs. 6-8).

Why a kernel: the global step is elementwise over EVERY parameter and
strictly memory-bound (roofline: ~0 FLOP/byte).  Unfused, XLA materializes
delta / u / sign as separate HBM round-trips; the fused kernel streams
x0, m, x_tau through VMEM once and writes x_new, m_new — 3 reads + 2
writes, the HBM-traffic lower bound for this update.

TPU mapping: flat parameter slabs are reshaped to (rows, 128) (lane-aligned)
and tiled (BLOCK_ROWS, 128) into VMEM — 5 live tiles = ~1.3 MB VMEM, well
under the ~16 MB/core budget, letting the DMA pipeline hide latency.
gamma arrives as a (1, 1) tile (it changes every step under a LR schedule;
hyper-parameters are compile-time constants).

Validated on CPU with interpret=True against ref.dsm_update_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 512  # (512, 128) f32 tile = 256 KiB; 5 tiles live = 1.25 MiB VMEM


def _dsm_kernel(gamma_ref, x0_ref, m_ref, xt_ref, x_out_ref, m_out_ref,
                *, eta, beta1, beta2, lam):
    g = gamma_ref[0, 0]
    x0 = x0_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    xt = xt_ref[...].astype(jnp.float32)
    delta = (x0 - xt) / g
    u = beta1 * m + (1.0 - beta1) * delta
    x_new = x0 - eta * g * (jnp.sign(u) + lam * x0)
    m_new = beta2 * m + (1.0 - beta2) * delta
    x_out_ref[...] = x_new.astype(x_out_ref.dtype)
    m_out_ref[...] = m_new.astype(m_out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eta", "beta1", "beta2", "lam", "interpret")
)
def dsm_update_2d(x0, m, xt, gamma, *, eta, beta1, beta2, lam, interpret=False):
    """x0/m/xt: (rows, 128). Returns (x_new, m_new)."""
    rows = x0.shape[0]
    br = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, br),)
    gamma_arr = jnp.reshape(gamma.astype(jnp.float32), (1, 1))

    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_dsm_kernel, eta=eta, beta1=beta1, beta2=beta2, lam=lam),
        grid=grid,
        in_specs=[scalar, tile, tile, tile],
        out_specs=[tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct(x0.shape, x0.dtype),
            jax.ShapeDtypeStruct(m.shape, m.dtype),
        ],
        interpret=interpret,
    )(gamma_arr, x0, m, xt)
