"""jit'd wrappers: pytree <-> lane-aligned 2D slabs for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU and are validated in interpret mode).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.adamw_update import adamw_update_2d
from repro.kernels.dsm_update import LANES, dsm_update_2d

PyTree = Any


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_2d(x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // LANES)
    pad = rows * LANES - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, LANES), n


def _from_2d(x2: jnp.ndarray, n: int, shape, dtype) -> jnp.ndarray:
    return x2.reshape(-1)[:n].reshape(shape).astype(dtype)


def dsm_update_tree(x0: PyTree, m: PyTree, x_tau: PyTree, gamma, *,
                    eta: float, beta1: float, beta2: float, lam: float,
                    interpret: bool = None) -> tuple[PyTree, PyTree]:
    """Apply the fused global sign-momentum kernel leafwise."""
    interpret = _default_interpret() if interpret is None else interpret
    gamma = jnp.asarray(gamma, jnp.float32)

    def leaf(x0_l, m_l, xt_l):
        x2, n = _to_2d(x0_l)
        m2, _ = _to_2d(m_l)
        t2, _ = _to_2d(xt_l.astype(x0_l.dtype))
        xn, mn = dsm_update_2d(
            x2, m2, t2, gamma, eta=eta, beta1=beta1, beta2=beta2, lam=lam,
            interpret=interpret,
        )
        return (
            _from_2d(xn, n, x0_l.shape, x0_l.dtype),
            _from_2d(mn, n, m_l.shape, m_l.dtype),
        )

    x_leaves, treedef = jax.tree.flatten(x0)
    m_leaves = jax.tree.leaves(m)
    t_leaves = jax.tree.leaves(x_tau)
    outs = [leaf(a, b, c) for a, b, c in zip(x_leaves, m_leaves, t_leaves)]
    new_x = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_x, new_m


def adamw_update_tree(params: PyTree, grads: PyTree, m: PyTree, v: PyTree,
                      gamma, step, *, beta1: float = 0.9, beta2: float = 0.95,
                      eps: float = 1e-8, wd: float = 0.1,
                      interpret: bool = None):
    """Apply the fused AdamW kernel leafwise. Returns (params, m, v)."""
    interpret = _default_interpret() if interpret is None else interpret
    gamma = jnp.asarray(gamma, jnp.float32)
    step = jnp.asarray(step, jnp.float32)

    def leaf(p_l, g_l, m_l, v_l):
        p2, n = _to_2d(p_l)
        g2, _ = _to_2d(g_l)
        m2, _ = _to_2d(m_l)
        v2, _ = _to_2d(v_l)
        pn, mn, vn = adamw_update_2d(
            p2, g2, m2, v2, gamma, step,
            beta1=beta1, beta2=beta2, eps=eps, wd=wd, interpret=interpret,
        )
        return (
            _from_2d(pn, n, p_l.shape, p_l.dtype),
            _from_2d(mn, n, m_l.shape, jnp.float32),
            _from_2d(vn, n, v_l.shape, jnp.float32),
        )

    p_leaves, treedef = jax.tree.flatten(params)
    outs = [
        leaf(a, b, c, d)
        for a, b, c, d in zip(
            p_leaves, jax.tree.leaves(grads), jax.tree.leaves(m), jax.tree.leaves(v)
        )
    ]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in outs]),
        jax.tree.unflatten(treedef, [o[1] for o in outs]),
        jax.tree.unflatten(treedef, [o[2] for o in outs]),
    )
