"""Pure-jnp oracles for the Pallas kernels (the allclose targets).

These mirror, bit-for-bit in f32 math, what the fused kernels compute:
  * dsm_update  — the paper's global sign-momentum step (eqs. 6-8)
  * adamw_update — one fused AdamW local step (Alg. 2)
"""

from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def dsm_update_ref(x0, m, x_tau, gamma, *, eta, beta1, beta2, lam):
    """Returns (x_new, m_new). Shapes alike; x dtype preserved, m stays f32."""
    x0f = x0.astype(F32)
    mf = m.astype(F32)
    delta = (x0f - x_tau.astype(F32)) / gamma
    u = beta1 * mf + (1.0 - beta1) * delta
    x_new = x0f - eta * gamma * (jnp.sign(u) + lam * x0f)
    m_new = beta2 * mf + (1.0 - beta2) * delta
    return x_new.astype(x0.dtype), m_new.astype(m.dtype)


def adamw_update_ref(p, g, m, v, gamma, step, *, beta1, beta2, eps, wd):
    """One AdamW step. step is 0-indexed; bias correction uses step+1."""
    pf, gf = p.astype(F32), g.astype(F32)
    m_new = beta1 * m.astype(F32) + (1.0 - beta1) * gf
    v_new = beta2 * v.astype(F32) + (1.0 - beta2) * gf * gf
    c = (step + 1.0).astype(F32)
    mhat = m_new / (1.0 - beta1 ** c)
    vhat = v_new / (1.0 - beta2 ** c)
    p_new = pf - gamma * (mhat / (jnp.sqrt(vhat) + eps) + wd * pf)
    return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)
