"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh, with NO real allocation
(ShapeDtypeStruct inputs, AOT lower/compile only).

MUST set the device-count flag before any other import (jax locks device
count on first init).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, ARCH_IDS, arch_supports_shape, load_arch
from repro.configs import specs as S
from repro.core import DSMConfig, constant, dsm_init, get_base_optimizer, make_dsm_step
from repro.core.dsm import DSMState
from repro.distributed import sharding as shd
from repro.launch.mesh import (
    MODEL_PAR,
    make_production_mesh,
    mesh_dims,
    serving_mesh,
    training_mesh,
)
from repro.models import transformer as T

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e) for the roofline terms
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_COLLECTIVE_RE = re.compile(
    r"=(.*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result sizes per collective kind from (partitioned) HLO text.

    all-reduce moves ~2x its payload on a ring (RS + AG); the others ~1x.
    """
    out = {k: 0 for k in
           ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute")}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        # group(1) = the (possibly tuple) result type, incl. /*index*/ comments
        out[m.group(2).lower()] += _shape_bytes(m.group(1))
    out["wire_bytes"] = (
        2 * out["all-reduce"] + out["all-gather"] + out["reduce-scatter"]
        + out["all-to-all"] + out["collective-permute"]
    )
    return out


# ---------------------------------------------------------------------------
# Lowering builders
# ---------------------------------------------------------------------------

ATTN_NAMES = ("wq", "wk", "wv", "wo")


def _state_shardings(state_sds: DSMState, mesh, zero: int, zero_global_buffers: bool,
                     replicate_names: tuple = ()):
    """Sharding tree for DSMState."""
    n2 = partial(shd.to_named, mesh=mesh)
    wspec = shd.param_pspecs(state_sds.params, model=MODEL_PAR, zero=zero,
                             worker_axis=True, replicate_names=replicate_names)
    gzero_axes = ("worker", "zero") if zero_global_buffers else ("zero",)
    n_workers = mesh.devices.shape[0]
    gzero = zero * (n_workers if zero_global_buffers else 1)
    gspec = shd.param_pspecs(state_sds.x0, model=MODEL_PAR, zero=gzero,
                             zero_axes=gzero_axes, replicate_names=replicate_names)
    mspec = shd.param_pspecs(state_sds.m, model=MODEL_PAR, zero=gzero,
                             zero_axes=gzero_axes, replicate_names=replicate_names)
    bspec = shd.param_pspecs(state_sds.base_state, model=MODEL_PAR, zero=zero,
                             worker_axis=True, replicate_names=replicate_names)
    return DSMState(
        params=n2(wspec), x0=n2(gspec), m=n2(mspec), base_state=n2(bspec),
        t=NamedSharding(mesh, P()), inner=NamedSharding(mesh, P()),
    )


def build_train(arch_id: str, shape_name: str, multi_pod: bool,
                zero_global_buffers: bool = True, tau: int = None,
                base_mesh=None):
    mod = load_arch(arch_id)
    cfg, topo = mod.FULL, mod.TOPO
    shape = INPUT_SHAPES[shape_name]
    base = base_mesh if base_mesh is not None else make_production_mesh(multi_pod=multi_pod)
    W = topo.n_workers_multi if multi_pod else topo.n_workers_single
    mesh = training_mesh(base, W)
    zero = mesh.devices.shape[1]

    base_opt = get_base_optimizer(topo.base_opt)
    dsm_cfg = DSMConfig(tau=tau or topo.tau)
    sched = constant(3e-4)
    loss = lambda p, b: T.loss_fn(
        p, b, cfg, remat=topo.remat,
        remat_policy=getattr(topo, "remat_policy", "full"))
    step = make_dsm_step(loss, base_opt, dsm_cfg, sched)

    aps = S.abstract_params(cfg)
    mdt = jnp.dtype(topo.momentum_dtype)
    state_sds = jax.eval_shape(lambda p: dsm_init(p, base_opt, W, momentum_dtype=mdt), aps)
    batch_sds = S.train_batch_specs(cfg, topo, shape, W)

    rep = () if topo.attn_tp else ATTN_NAMES
    state_sh = _state_shardings(state_sds, mesh, zero, zero_global_buffers, rep)
    batch_sh = shd.to_named(shd.train_batch_pspecs(batch_sds, zero, MODEL_PAR), mesh)
    metrics_sh = {
        "loss": NamedSharding(mesh, P()),
        "gamma": NamedSharding(mesh, P()),
        "last_loss": NamedSharding(mesh, P()),
    }

    with mesh:
        lowered = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, metrics_sh),
            donate_argnums=(0,),   # reuse state buffers (params/m/x0/moments)
        ).lower(state_sds, batch_sds)
    return lowered, mesh


def build_prefill(arch_id: str, shape_name: str, multi_pod: bool, base_mesh=None,
                  unroll: bool = False):
    mod = load_arch(arch_id)
    cfg = mod.FULL
    shape = INPUT_SHAPES[shape_name]
    base = base_mesh if base_mesh is not None else make_production_mesh(multi_pod=multi_pod)
    mesh = serving_mesh(base)
    data = mesh.devices.shape[0]

    aps = S.abstract_params(cfg)
    batch_sds = S.prefill_batch_specs(cfg, shape)

    pspec = shd.param_pspecs(aps, model=MODEL_PAR, zero=data, zero_axes=("data",))
    params_sh = shd.to_named(pspec, mesh)
    batch_sh = shd.to_named(
        shd.serve_batch_pspecs(batch_sds, data, MODEL_PAR), mesh)

    fn = lambda p, b: T.prefill(p, b, cfg, remat=True, unroll=unroll)
    out_sds = jax.eval_shape(fn, aps, batch_sds)
    logits_sh = NamedSharding(mesh, P("data", "model"))
    cache_sh = shd.to_named(
        shd.cache_pspecs(out_sds[1], data, MODEL_PAR), mesh)

    with mesh:
        lowered = jax.jit(
            fn, in_shardings=(params_sh, batch_sh),
            out_shardings=(logits_sh, cache_sh),
        ).lower(aps, batch_sds)
    return lowered, mesh


def build_decode(arch_id: str, shape_name: str, multi_pod: bool, base_mesh=None,
                 unroll: bool = False):
    mod = load_arch(arch_id)
    cfg = mod.FULL
    shape = INPUT_SHAPES[shape_name]
    base = base_mesh if base_mesh is not None else make_production_mesh(multi_pod=multi_pod)
    mesh = serving_mesh(base)
    data = mesh.devices.shape[0]

    aps = S.abstract_params(cfg)
    dspecs = S.decode_specs(cfg, shape)

    pspec = shd.param_pspecs(aps, model=MODEL_PAR, zero=data, zero_axes=("data",))
    params_sh = shd.to_named(pspec, mesh)
    cache_sh = shd.to_named(shd.cache_pspecs(dspecs["cache"], data, MODEL_PAR), mesh)
    tok_sh = NamedSharding(
        mesh, P("data") if shape.global_batch % data == 0 and shape.global_batch >= data else P())
    pos_sh = NamedSharding(mesh, P())
    B = shape.global_batch
    logits_sh = NamedSharding(
        mesh, P("data", "model") if B % data == 0 and B >= data else P(None, "model"))

    fn = lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg, unroll=unroll)
    with mesh:
        lowered = jax.jit(
            fn,
            in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
            out_shardings=(logits_sh, cache_sh),
        ).lower(aps, dspecs["cache"], dspecs["tokens"], dspecs["pos"])
    return lowered, mesh


def build(arch_id: str, shape_name: str, multi_pod: bool, **kw):
    kind = INPUT_SHAPES[shape_name].kind
    if kind == "train":
        return build_train(arch_id, shape_name, multi_pod, **kw)
    if kind == "prefill":
        return build_prefill(arch_id, shape_name, multi_pod, **kw)
    return build_decode(arch_id, shape_name, multi_pod, **kw)


# ---------------------------------------------------------------------------
# Roofline terms from the compiled artifact
# ---------------------------------------------------------------------------

def analyze(lowered, compiled, n_chips: int) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
    }
    # cost_analysis flops/bytes are per-device for an SPMD-partitioned module
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll["wire_bytes"] / ICI_BW
    dominant = max(
        [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
        key=lambda kv: kv[1],
    )[0]
    return {
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "collectives": coll,
        "memory": mem_d,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "n_chips": n_chips,
    }


def run_one(arch_id: str, shape_name: str, multi_pod: bool, outdir: str, **kw) -> dict:
    tag = f"{arch_id}.{shape_name}.{'multipod' if multi_pod else 'singlepod'}"
    t0 = time.time()
    try:
        lowered, mesh = build(arch_id, shape_name, multi_pod, **kw)
        compiled = lowered.compile()
        rec = analyze(lowered, compiled, mesh.devices.size)
        rec.update(status="ok", arch=arch_id, shape=shape_name,
                   multi_pod=multi_pod, mesh=mesh_dims(mesh),
                   compile_s=round(time.time() - t0, 1))
    except Exception as e:  # noqa: BLE001 — record failures, they are bugs
        rec = {
            "status": "error", "arch": arch_id, "shape": shape_name,
            "multi_pod": multi_pod, "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
            "compile_s": round(time.time() - t0, 1),
        }
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--no-zero-global-buffers", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch_id in archs:
        mod = load_arch(arch_id)
        for shape_name in shapes:
            if not arch_supports_shape(mod.FULL, mod.TOPO, shape_name):
                print(f"SKIP {arch_id} x {shape_name} (DESIGN.md: sub-quadratic only)")
                continue
            for mp in meshes:
                kw = {}
                if INPUT_SHAPES[shape_name].kind == "train" and args.no_zero_global_buffers:
                    kw["zero_global_buffers"] = False
                rec = run_one(arch_id, shape_name, mp, args.outdir, **kw)
                mark = "OK " if rec["status"] == "ok" else "ERR"
                extra = (
                    f"dom={rec.get('dominant')} "
                    f"tc={rec.get('t_compute_s', 0):.3e} tm={rec.get('t_memory_s', 0):.3e} "
                    f"tn={rec.get('t_collective_s', 0):.3e} "
                    f"peakGB={rec.get('memory', {}).get('peak_bytes', 0)/1e9:.2f}"
                    if rec["status"] == "ok" else rec.get("error", "")[:200]
                )
                print(f"{mark} {arch_id:28s} {shape_name:12s} "
                      f"{'multi' if mp else 'single'} ({rec['compile_s']}s) {extra}",
                      flush=True)


if __name__ == "__main__":
    main()
