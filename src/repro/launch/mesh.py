"""Production meshes and derived (worker, zero, model) training meshes.

``make_production_mesh`` is a FUNCTION (not module-level) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

MODEL_PAR = 16  # chips along the model axis (both meshes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def training_mesh(base_mesh: Mesh, n_workers: int) -> Mesh:
    """Reshape the production mesh into (worker, zero, model).

    The paper's worker i = one model-parallel group; ``zero`` is the FSDP
    shard inside a worker (paper §2's intra-node ZeRO).  pod x data rows are
    split into ``n_workers`` groups of ``zero`` rows each.
    """
    devices = np.asarray(base_mesh.devices)
    model = devices.shape[-1]
    rows = devices.reshape(-1, model)          # (pod*data, model)
    n_rows = rows.shape[0]
    if n_rows % n_workers != 0:
        raise ValueError(
            f"n_workers={n_workers} does not divide the {n_rows} model-parallel "
            f"groups of the production mesh {tuple(devices.shape)}; pick a "
            f"worker count from the divisors of {n_rows}"
        )
    zero = n_rows // n_workers
    grid = rows.reshape(n_workers, zero, model)
    return Mesh(grid, ("worker", "zero", "model"))


def host_training_mesh(n_workers: int, model: int = 1) -> Mesh:
    """(worker, zero, model) mesh over the *local* devices.

    The single code path for every mesh-consuming trainer feature
    (``zero_sharded``, ``device_parallel_local``; also the device-parallel
    tests under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    The worker axis matches ``n_workers`` when the device grid allows; a
    single-device host degrades to worker=1 (so the same code runs on one
    CPU device), and any other mismatch is an error — silently replicating
    workers on a multi-device grid would defeat the sharding it names.
    """
    devices = np.array(jax.devices())
    n = (len(devices) // model) * model
    if n < 1:
        raise ValueError(
            f"host_training_mesh needs at least model={model} devices, "
            f"have {len(devices)}"
        )
    rows = n // model
    if rows % n_workers == 0:
        worker = n_workers
    elif rows == 1:
        worker = 1  # single-device degenerate mesh
    else:
        raise ValueError(
            f"n_workers={n_workers} does not divide the host device grid "
            f"({len(devices)} devices / model={model} -> {rows} rows); pick "
            f"a worker count from the divisors of {rows}"
        )
    zero = rows // worker
    grid = devices[: worker * zero * model].reshape(worker, zero, model)
    return Mesh(grid, ("worker", "zero", "model"))


def serving_mesh(base_mesh: Mesh) -> Mesh:
    """Reshape into (data, model) with pod folded into data."""
    devices = np.asarray(base_mesh.devices)
    model = devices.shape[-1]
    rows = devices.reshape(-1, model)
    return Mesh(rows, ("data", "model"))


def mesh_dims(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
