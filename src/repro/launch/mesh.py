"""Production meshes and derived (worker, zero, model) training meshes.

``make_production_mesh`` is a FUNCTION (not module-level) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

MODEL_PAR = 16  # chips along the model axis (both meshes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def training_mesh(base_mesh: Mesh, n_workers: int) -> Mesh:
    """Reshape the production mesh into (worker, zero, model).

    The paper's worker i = one model-parallel group; ``zero`` is the FSDP
    shard inside a worker (paper §2's intra-node ZeRO).  pod x data rows are
    split into ``n_workers`` groups of ``zero`` rows each.
    """
    devices = np.asarray(base_mesh.devices)
    model = devices.shape[-1]
    rows = devices.reshape(-1, model)          # (pod*data, model)
    n_rows = rows.shape[0]
    assert n_rows % n_workers == 0, (n_rows, n_workers)
    zero = n_rows // n_workers
    grid = rows.reshape(n_workers, zero, model)
    return Mesh(grid, ("worker", "zero", "model"))


def host_training_mesh(n_workers: int, model: int = 1) -> Mesh:
    """(worker, zero, model) mesh over the *local* devices.

    Used by the trainer's ZeRO-sharded path (and the device-parallel tests
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).  The
    worker axis matches ``n_workers`` when the device count allows;
    otherwise it degrades to worker=1 (pure zero sharding), so the same
    code runs on a single CPU device.
    """
    devices = np.array(jax.devices())
    n = (len(devices) // model) * model
    assert n >= 1, "no devices"
    rows = n // model
    worker = n_workers if rows % n_workers == 0 and rows >= n_workers else 1
    zero = rows // worker
    grid = devices[: worker * zero * model].reshape(worker, zero, model)
    return Mesh(grid, ("worker", "zero", "model"))


def serving_mesh(base_mesh: Mesh) -> Mesh:
    """Reshape into (data, model) with pod folded into data."""
    devices = np.asarray(base_mesh.devices)
    model = devices.shape[-1]
    rows = devices.reshape(-1, model)
    return Mesh(rows, ("data", "model"))


def mesh_dims(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
