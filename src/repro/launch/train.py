"""Training launcher.

Two modes:
  * local (default)   — really trains on the available devices (CPU here):
      PYTHONPATH=src python -m repro.launch.train --arch gpt2_small_smoke \\
          --algorithm dsm --tau 12 --steps 100
    ``--arch`` accepts ``<id>`` (FULL config — only sensible on a real
    cluster), ``<id>_smoke`` (reduced family variant), or ``nano``.
  * plan              — prints the production launch plan for the 16x16 /
    2x16x16 mesh (worker count, shardings, per-chip memory from the
    dry-run artifact) without touching devices:
      python -m repro.launch.train --arch deepseek_67b --plan
"""

from __future__ import annotations

import argparse
import json
import os

from repro.configs import load_arch
from repro.configs.base import ModelConfig


def _resolve_arch(name: str) -> tuple[ModelConfig, object]:
    if name == "nano":
        from benchmarks.tables import NANO

        cfg = NANO
        topo = load_arch("gpt2_small").TOPO
        return cfg, topo
    if name.endswith("_smoke"):
        mod = load_arch(name[: -len("_smoke")])
        return mod.SMOKE, mod.TOPO
    mod = load_arch(name)
    return mod.FULL, mod.TOPO


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="nano")
    ap.add_argument("--algorithm", default="dsm",
                    choices=("dsm", "slowmo", "signed_slowmo", "lookahead",
                             "signed_lookahead", "global_adamw", "local_avg",
                             "perstep", "mv_signsgd"))
    ap.add_argument("--base-opt", default=None)
    ap.add_argument("--tau", type=int, default=None)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--n-workers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--b-micro", type=int, default=4)
    ap.add_argument("--peak-lr", type=float, default=5e-3)
    ap.add_argument("--global-lr", type=float, default=0.3)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--use-kernel", action="store_true",
                    help="fused Pallas kernel for the DSM global step")
    ap.add_argument("--zero-sharded", action="store_true",
                    help="ZeRO-sharded global step over the local devices "
                         "(shard x0/m over worker*zero ranks)")
    ap.add_argument("--device-parallel-local", action="store_true",
                    help="run the tau local steps shard_mapped over the "
                         "worker mesh axis (each device computes only its "
                         "own worker; no inter-worker collectives)")
    # --- robustness (docs/fault_tolerance.md) ---
    ap.add_argument("--faults", default=None,
                    help="seeded fault-injection spec, e.g. "
                         "'drop=0.25,straggle=0.1,nan=0.05,seed=0'")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="atomic rotated checkpoints of the full training "
                         "state land here")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="outer steps between checkpoints "
                         "(default: steps // 5)")
    ap.add_argument("--resume", action="store_true",
                    help="auto-resume bit-exactly from the latest complete "
                         "checkpoint in --checkpoint-dir")
    ap.add_argument("--guard-spike-factor", type=float, default=0.0,
                    help="skip rounds whose loss exceeds this factor times "
                         "the accepted-loss EMA (0 disables)")
    ap.add_argument("--guard-nonfinite", action="store_true",
                    help="skip rounds that produce NaN/inf anywhere in the "
                         "training state")
    # --- observability (docs/observability.md) ---
    ap.add_argument("--run-dir", default=None,
                    help="observability run directory: manifest.json, "
                         "events.jsonl (spans, comm ledger), scalars.csv; "
                         "inspect with `python -m repro.obs summarize <dir>`")
    ap.add_argument("--log-every", type=int, default=0,
                    help="metric flush + log cadence in outer steps "
                         "(default: the eval cadence)")
    ap.add_argument("--profile-steps", default=None, metavar="A:B",
                    help="capture a jax.profiler.trace for the inclusive "
                         "outer-step range A:B into <run-dir>/profile")
    # --- runtime sanitizers (docs/analysis.md) ---
    ap.add_argument("--sanitize", action="store_true",
                    help="transfer guard around the hot loop + recompilation "
                         "counter (the steady-state outer step must compile "
                         "exactly once)")
    ap.add_argument("--sanitize-nans", action="store_true",
                    help="run the loop under jax_debug_nans (chaos tier: "
                         "masked NaNs must never reach a jit output)")
    ap.add_argument("--plan", action="store_true")
    args = ap.parse_args()

    cfg, topo = _resolve_arch(args.arch)
    tau = args.tau or topo.tau

    if args.plan:
        from repro.configs import specs as S

        n = S.param_count(cfg)
        plan = {
            "arch": args.arch,
            "params_B": round(n / 1e9, 3),
            "mesh_single_pod": {"shape": [16, 16], "axes": ["data", "model"],
                                "n_workers": topo.n_workers_single},
            "mesh_multi_pod": {"shape": [2, 16, 16], "axes": ["pod", "data", "model"],
                               "n_workers": topo.n_workers_multi},
            "tau": tau,
            "base_opt": topo.base_opt,
            "grad_accum": topo.grad_accum,
            "dryrun_cmd": (
                f"PYTHONPATH=src python -m repro.launch.dryrun --arch {args.arch} "
                "--shape train_4k --mesh both"),
        }
        dr = f"experiments/dryrun/{args.arch}.train_4k.singlepod.json"
        if os.path.exists(dr):
            rec = json.load(open(dr))
            plan["per_chip_peak_GB"] = round(rec["memory"]["peak_bytes"] / 1e9, 2)
            plan["dominant_roofline_term"] = rec.get("dominant")
        print(json.dumps(plan, indent=2))
        return

    from repro.data.pipeline import MarkovCorpus
    from repro.train.trainer import TrainSettings, run_training

    s = TrainSettings(
        algorithm=args.algorithm, base_opt=args.base_opt or topo.base_opt,
        n_workers=args.n_workers, tau=tau, steps=args.steps, seq=args.seq,
        b_micro=args.b_micro, peak_lr=args.peak_lr, global_lr=args.global_lr,
        eval_every=max(args.steps // 5, 1),
        use_kernel=args.use_kernel, zero_sharded=args.zero_sharded,
        device_parallel_local=args.device_parallel_local,
        faults=args.faults,
        guard_nonfinite=args.guard_nonfinite,
        guard_spike_factor=args.guard_spike_factor,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        resume=args.resume,
        sanitize=args.sanitize,
        sanitize_nans=args.sanitize_nans,
        run_dir=args.run_dir,
        log_every=args.log_every,
        profile_steps=args.profile_steps,
    )
    corpus = MarkovCorpus(cfg.vocab_size, seed=1)
    result = run_training(cfg, s, corpus, log=print)
    print(f"final eval loss: {result['final_eval']:.4f} "
          f"(comm rounds: {result['comm_rounds']}, tokens: {result['tokens']}, "
          f"skipped rounds: {result['skipped_rounds']}, "
          f"rollbacks: {result['rollbacks']})")
    if args.run_dir:
        print(f"run dir: {args.run_dir} "
              f"(summarize: python -m repro.obs summarize {args.run_dir})")

    if args.checkpoint:
        from repro.checkpoint import checkpoint as CK

        CK.save(args.checkpoint, result["state"].x0
                if hasattr(result["state"], "x0") else result["state"].params,
                step=args.steps)
        print(f"saved checkpoint to {args.checkpoint}.npz")


if __name__ == "__main__":
    main()
