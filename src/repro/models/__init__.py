"""Model zoo: unified pattern-driven transformer + SSM/RG-LRU/MoE blocks."""

from repro.models.transformer import (
    decode_step,
    hidden_states,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)
