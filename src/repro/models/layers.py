"""Model building blocks: norms, RoPE, GQA attention (full / sliding-window /
decode), MLP, MoE (ragged_dot grouped matmul), Mamba-2 SSD, RG-LRU.

Everything is pure-functional: ``init_*(key, cfg) -> params`` and
``*_apply(params, x, ...) -> y``.  Activations run in ``cfg.act_dtype``
(bf16 on TPU), matmuls accumulate in f32 via ``preferred_element_type``.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any
F32 = jnp.float32


def _init_dense(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> PyTree:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: PyTree, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(F32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"].astype(F32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=F32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (S,) or (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(F32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]              # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA) — training/prefill path with blockwise-causal computation
# ---------------------------------------------------------------------------

def init_attention(key, cfg, cross: bool = False) -> PyTree:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init_dense(ks[0], (d, h * hd), cfg.p_dtype),
        "wk": _init_dense(ks[1], (d, kvh * hd), cfg.p_dtype),
        "wv": _init_dense(ks[2], (d, kvh * hd), cfg.p_dtype),
        "wo": _init_dense(ks[3], (h * hd, d), cfg.p_dtype, scale=1.0 / math.sqrt(h * hd)),
    }
    return p


def _gqa_scores(q, k):
    """q: (B,Sq,H,hd), k: (B,Sk,KVH,hd) -> (B,KVH,rep,Sq,Sk) f32."""
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    rep = H // KVH
    qg = q.reshape(B, Sq, KVH, rep, hd)
    return jnp.einsum(
        "bqgrh,bkgh->bgrqk", qg, k, preferred_element_type=F32
    ) / math.sqrt(hd)


def _gqa_out(probs, v, out_dtype):
    """probs: (B,KVH,rep,Sq,Sk), v: (B,Sk,KVH,hd) -> (B,Sq,H,hd)."""
    B, KVH, rep, Sq, Sk = probs.shape
    out = jnp.einsum(
        "bgrqk,bkgh->bqgrh", probs.astype(v.dtype), v,
        preferred_element_type=v.dtype,
    )
    return out.reshape(B, Sq, KVH * rep, v.shape[-1]).astype(out_dtype)


def causal_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    window: Optional[int] = None,
    q_block: int = 1024,
) -> jnp.ndarray:
    """Blockwise causal (optionally sliding-window) attention.

    Unrolled static loop over query tiles; each tile attends only to the
    (block-aligned) keys it can see, so FLOPs match causal/windowed exactly
    (up to one diagonal tile) and the score buffer stays O(q_block * Sk_vis).
    """
    B, S, H, hd = q.shape
    qb = min(q_block, S)
    n_blocks = -(-S // qb)
    outs = []
    for i in range(n_blocks):
        q_start, q_end = i * qb, min((i + 1) * qb, S)
        qi = q[:, q_start:q_end]
        k_start = 0 if window is None else max(0, (q_start - window) // qb * qb)
        ki = k[:, k_start:q_end]
        vi = v[:, k_start:q_end]
        scores = _gqa_scores(qi, ki)                      # (B,g,r,sq,sk)
        q_pos = jnp.arange(q_start, q_end)[:, None]
        k_pos = jnp.arange(k_start, q_end)[None, :]
        mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        outs.append(_gqa_out(probs, vi, q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def full_attention(q, k, v, mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Bidirectional (encoder / cross) attention, direct."""
    scores = _gqa_scores(q, k)
    if mask is not None:
        scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v, q.dtype)


def decode_attention(q, k_cache, v_cache, valid_mask) -> jnp.ndarray:
    """One-token query vs a KV cache.

    q: (B,1,H,hd); caches: (B,S,KVH,hd); valid_mask: (S,) or (B,S) bool.
    """
    scores = _gqa_scores(q, k_cache)                      # (B,g,r,1,S)
    if valid_mask.ndim == 1:
        m = valid_mask[None, None, None, None, :]
    else:
        m = valid_mask[:, None, None, None, :]
    scores = jnp.where(m, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v_cache, q.dtype)


def attn_qkv(p: PyTree, x: jnp.ndarray, positions, cfg) -> tuple:
    B = x.shape[0]
    S = x.shape[1]
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, cfg.hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_proj_out(p: PyTree, out: jnp.ndarray) -> jnp.ndarray:
    B, S, H, hd = out.shape
    return out.reshape(B, S, H * hd) @ p["wo"].astype(out.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU or plain)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff: Optional[int] = None) -> PyTree:
    d, dff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w1": _init_dense(ks[0], (d, dff), cfg.p_dtype),
        "w2": _init_dense(ks[1], (dff, d), cfg.p_dtype),
    }
    if cfg.mlp_gated:
        p["w3"] = _init_dense(ks[2], (d, dff), cfg.p_dtype)
    return p


def mlp_apply(p: PyTree, x: jnp.ndarray, cfg) -> jnp.ndarray:
    a = act_fn(cfg.act)
    h = a(x @ p["w1"].astype(x.dtype))
    if "w3" in p:
        h = h * (x @ p["w3"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE: top-k routing + ragged_dot grouped matmul (FLOPs-exact for active
# experts; expert weights are tensor-parallel over the model axis, see
# DESIGN.md §5 — no all-to-all, the d_ff dims shard like a dense MLP).
# ---------------------------------------------------------------------------

def init_moe(key, cfg) -> PyTree:
    d, dff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _init_dense(ks[0], (d, E), F32, scale=0.02),
        "we1": _init_dense(ks[1], (E, d, dff), cfg.p_dtype),
        "we2": _init_dense(ks[2], (E, dff, d), cfg.p_dtype),
    }
    if cfg.mlp_gated:
        p["we3"] = _init_dense(ks[3], (E, d, dff), cfg.p_dtype)
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.d_ff * cfg.n_shared_experts)
    return p


def moe_apply(p: PyTree, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out, aux_loss). x: (B,S,d)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(B * S, d)
    T = B * S

    logits = (xt.astype(F32) @ p["router"])               # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)       # (T,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], E, dtype=F32), axis=0
    )
    mean_probs = probs.mean(axis=0)
    aux_loss = E * jnp.sum(density * mean_probs)

    if getattr(cfg, "moe_impl", "ragged") == "dense":
        # masked dense: every expert computes every token; gates zero out the
        # inactive ones.  FLOPs are E/K x the active count, but every matmul
        # is a clean MXU-aligned TP einsum with ONE (T,d) reduce at the end —
        # the right trade for sub-1k d_ff experts (see EXPERIMENTS.md SPerf).
        gates_dense = jnp.zeros((T, E), dtype=xt.dtype)
        gates_dense = gates_dense.at[
            jnp.arange(T)[:, None], expert_idx
        ].set(gate_vals.astype(xt.dtype))
        a = act_fn(cfg.act)
        h = a(jnp.einsum("td,edf->tef", xt, p["we1"].astype(xt.dtype)))
        if "we3" in p:
            h = h * jnp.einsum("td,edf->tef", xt, p["we3"].astype(xt.dtype))
        out = jnp.einsum("tef,efd,te->td", h, p["we2"].astype(xt.dtype), gates_dense)
        if "shared" in p:
            out = out + mlp_apply(p["shared"], xt, cfg)
        return out.reshape(B, S, d), aux_loss

    # sort token-expert assignments by expert
    flat_expert = expert_idx.reshape(T * K)
    sort_idx = jnp.argsort(flat_expert)                   # (TK,)
    token_of = sort_idx // K
    xs = xt[token_of]                                     # (TK, d)
    group_sizes = jnp.bincount(flat_expert, length=E).astype(jnp.int32)

    a = act_fn(cfg.act)
    h = a(jax.lax.ragged_dot(xs, p["we1"].astype(xs.dtype), group_sizes))
    if "we3" in p:
        h = h * jax.lax.ragged_dot(xs, p["we3"].astype(xs.dtype), group_sizes)
    y = jax.lax.ragged_dot(h, p["we2"].astype(xs.dtype), group_sizes)  # (TK, d)

    if getattr(cfg, "moe_combine", "scatter") == "ksum":
        # combine-before-reduce: unsort to (T, K, d) and contract K with the
        # gates BEFORE any cross-shard reduction becomes necessary — shrinks
        # the row-parallel all-reduce from TK rows to T rows (8x for top-8).
        inv = jnp.argsort(sort_idx)
        y_tk = y[inv].reshape(T, K, d)
        out = jnp.einsum("tkd,tk->td", y_tk, gate_vals.astype(y.dtype))
    else:
        w = gate_vals.reshape(T * K)[sort_idx].astype(y.dtype)
        out = jnp.zeros_like(xt).at[token_of].add(y * w[:, None])

    if "shared" in p:
        out = out + mlp_apply(p["shared"], xt, cfg)
    return out.reshape(B, S, d), aux_loss


# ---------------------------------------------------------------------------
# Depthwise causal conv (Mamba-2 / RG-LRU front conv)
# ---------------------------------------------------------------------------

def init_conv1d(key, channels: int, width: int, dtype) -> PyTree:
    return {
        "w": _init_dense(key, (width, channels), dtype, scale=1.0 / math.sqrt(width)),
        "b": jnp.zeros((channels,), dtype),
    }


def conv1d_apply(p: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    """Causal depthwise conv. x: (B,S,C)."""
    width = p["w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1]] * p["w"][i].astype(x.dtype) for i in range(width)
    )
    return out + p["b"].astype(x.dtype)


def conv1d_step(p: PyTree, conv_state: jnp.ndarray, x_t: jnp.ndarray):
    """Decode: conv_state (B,width-1,C), x_t (B,C) -> (y_t, new_state)."""
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B,width,C)
    y = jnp.einsum("bwc,wc->bc", window.astype(F32), p["w"].astype(F32))
    y = (y + p["b"].astype(F32)).astype(x_t.dtype)
    return y, window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-2 (SSD — state-space duality, chunked)  [arXiv:2405.21060]
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg) -> PyTree:
    d, di = cfg.d_model, cfg.d_inner
    N, H = cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 5)
    conv_ch = di + 2 * N  # conv over (x, B, C) streams
    return {
        # in_proj -> [z (di), x (di), B (N), C (N), dt (H)]
        "in_proj": _init_dense(ks[0], (d, 2 * di + 2 * N + H), cfg.p_dtype),
        "conv": init_conv1d(ks[1], conv_ch, cfg.conv_width, cfg.p_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=F32)),
        "D": jnp.ones((H,), F32),
        "dt_bias": jnp.zeros((H,), F32),
        "norm": init_rmsnorm(di, cfg.p_dtype),
        "out_proj": _init_dense(ks[2], (di, d), cfg.p_dtype),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., L) -> (..., L, L) with out[i,j] = sum_{j<k<=i} x[k]; -inf above diag."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int = 128):
    """Mamba-2 SSD scan, chunked (minimal version of paper Listing 1).

    x: (B,S,H,P) value heads; dt: (B,S,H) >0; A: (H,) >0 decay rate;
    Bm, Cm: (B,S,N) single-group input/output projections.
    Returns y: (B,S,H,P).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, "sequence length must be divisible by ssd chunk"

    dA = (-A[None, None, :] * dt).astype(F32)             # (B,S,H) log-decay (<0)
    xw = (x.astype(F32) * dt[..., None])                  # dt-weighted input

    # reshape into chunks
    c = lambda t: t.reshape(Bsz, nc, chunk, *t.shape[2:])
    dAc, xc = c(dA), c(xw)                                # (B,nc,Q,H), (B,nc,Q,H,P)
    Bc, Cc = c(Bm.astype(F32)), c(Cm.astype(F32))         # (B,nc,Q,N)

    dAc_h = jnp.moveaxis(dAc, -1, 2)                      # (B,nc,H,Q)
    A_cum = jnp.cumsum(dAc_h, axis=-1)                    # (B,nc,H,Q)

    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dAc_h))                           # (B,nc,H,Q,Q)
    Y_diag = jnp.einsum("bcqn,bckn,bchqk,bckhp->bcqhp", Cc, Bc, L, xc)

    # 2) chunk states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)       # (B,nc,H,Q)
    states = jnp.einsum("bckn,bchk,bckhp->bchpn", Bc, decay_states, xc)

    # 3) inter-chunk recurrence over nc
    chunk_decay = jnp.exp(A_cum[..., -1])                 # (B,nc,H)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                                  # emit state *before* chunk

    init = jnp.zeros((Bsz, H, P, N), F32)
    _, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (B,nc,H,P,N)

    # 4) state -> output within chunk
    state_decay_out = jnp.exp(A_cum)                      # (B,nc,H,Q)
    Y_off = jnp.einsum("bcqn,bchpn,bchq->bcqhp", Cc, prev_states, state_decay_out)

    y = (Y_diag + Y_off).reshape(Bsz, S, H, P)
    return y


def mamba2_apply(p: PyTree, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Training/prefill path. x: (B,S,d) -> (B,S,d)."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xs, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], -1)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(conv1d_apply(p["conv"], conv_in))
    xs, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])   # (B,S,H)
    A = jnp.exp(p["A_log"])                               # (H,) > 0
    xh = xs.reshape(*xs.shape[:2], H, P)
    y = ssd_chunked(xh, dt, A, Bm, Cm, chunk=min(128, xs.shape[1]))
    y = y + p["D"][None, None, :, None] * xh.astype(F32)
    y = y.reshape(*xs.shape[:2], di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"].astype(x.dtype)


def mamba2_decode(p: PyTree, cache: PyTree, x_t: jnp.ndarray, cfg):
    """One-token recurrent step. x_t: (B,d); cache: {state:(B,H,P,N), conv:(B,w-1,C)}."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x_t @ p["in_proj"].astype(x_t.dtype)
    z, xs, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], -1)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_y, new_conv = conv1d_step(p["conv"], cache["conv"], conv_in)
    conv_y = jax.nn.silu(conv_y)
    xs, Bm, Cm = jnp.split(conv_y, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])   # (B,H)
    A = jnp.exp(p["A_log"])
    dA = jnp.exp(-A[None] * dt)                           # (B,H)
    xh = xs.reshape(-1, H, P).astype(F32)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm.astype(F32), xh)
    new_state = cache["state"] * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(F32))
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(-1, di).astype(x_t.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(x_t.dtype)
    return out, {"state": new_state, "conv": new_conv}


def mamba2_init_cache(cfg, batch: int, dtype) -> PyTree:
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_ch = di + 2 * N
    return {
        "state": jnp.zeros((batch, H, P, N), F32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)  [arXiv:2402.19427]
# ---------------------------------------------------------------------------

def init_rglru(key, cfg) -> PyTree:
    d, dr = cfg.d_model, cfg.d_rnn
    ks = jax.random.split(key, 6)
    return {
        "in_x": _init_dense(ks[0], (d, dr), cfg.p_dtype),
        "in_gate": _init_dense(ks[1], (d, dr), cfg.p_dtype),
        "conv": init_conv1d(ks[2], dr, cfg.conv_width, cfg.p_dtype),
        "w_a": _init_dense(ks[3], (dr, dr), cfg.p_dtype),   # recurrence gate
        "w_x": _init_dense(ks[4], (dr, dr), cfg.p_dtype),   # input gate
        "lam": jnp.full((dr,), 2.2, F32),  # softplus-param: a ~ sigmoid-ish decay
        "out": _init_dense(ks[5], (dr, d), cfg.p_dtype),
    }


_RGLRU_C = 8.0


def _rglru_coeffs(p, xc):
    """xc: (..., dr) conv output. Returns (a, b) of h = a*h_prev + b, f32."""
    r = jax.nn.sigmoid((xc @ p["w_a"].astype(xc.dtype)).astype(F32))
    i = jax.nn.sigmoid((xc @ p["w_x"].astype(xc.dtype)).astype(F32))
    log_a = -_RGLRU_C * r * jax.nn.softplus(p["lam"])      # <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i * xc.astype(F32))
    return a, b


def rglru_apply(p: PyTree, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Training/prefill: associative linear scan over S. x: (B,S,d)."""
    gate = jax.nn.gelu((x @ p["in_gate"].astype(x.dtype)).astype(F32), approximate=True)
    xr = x @ p["in_x"].astype(x.dtype)
    xc = conv1d_apply(p["conv"], xr)
    a, b = _rglru_coeffs(p, xc)                            # (B,S,dr)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h * gate).astype(x.dtype)
    return y @ p["out"].astype(x.dtype)


def rglru_decode(p: PyTree, cache: PyTree, x_t: jnp.ndarray, cfg):
    """x_t: (B,d); cache: {h:(B,dr) f32, conv:(B,w-1,dr)}."""
    gate = jax.nn.gelu((x_t @ p["in_gate"].astype(x_t.dtype)).astype(F32), approximate=True)
    xr = x_t @ p["in_x"].astype(x_t.dtype)
    xc, new_conv = conv1d_step(p["conv"], cache["conv"], xr)
    a, b = _rglru_coeffs(p, xc)                            # (B,dr)
    new_h = a * cache["h"] + b
    y = (new_h * gate).astype(x_t.dtype)
    return y @ p["out"].astype(x_t.dtype), {"h": new_h, "conv": new_conv}


def rglru_init_cache(cfg, batch: int, dtype) -> PyTree:
    return {
        "h": jnp.zeros((batch, cfg.d_rnn), F32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), dtype),
    }
