"""Unified sequence model: decoder-only LM (dense / MoE / sliding-window /
SSM / RG-LRU mixtures), encoder-decoder (whisper backbone), and VLM
(llava backbone) — all driven by ``ModelConfig.pattern``.

Layer stacking: full repeats of the pattern are *scanned* (params stacked on
a leading block axis — keeps HLO size O(pattern) instead of O(n_layers));
the remainder layers are unrolled.

Public API (used by trainer / dryrun / serve):
  init_params(key, cfg)                       -> params
  loss_fn(params, batch, cfg)                 -> scalar loss
  prefill(params, batch, cfg)                 -> (last_logits, cache)
  decode_step(params, cache, tokens, pos, cfg)-> (logits, cache)
  init_cache(cfg, batch, max_len, dtype)      -> cache
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

PyTree = Any
F32 = jnp.float32
MOE_AUX_COEF = 0.01
CE_CHUNK = 2048


def _parse_kind(kind: str) -> tuple[str, str]:
    mixer, _, ffn = kind.partition(":")
    return mixer, ffn or "dense"


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, kind: str, cfg) -> PyTree:
    mixer, ffn = _parse_kind(kind)
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": L.init_rmsnorm(cfg.d_model, cfg.p_dtype)}
    if mixer in ("attn", "swa", "encattn"):
        p["attn"] = L.init_attention(ks[0], cfg)
    elif mixer == "xattn":
        p["attn"] = L.init_attention(ks[0], cfg)
        p["xattn"] = L.init_attention(ks[3], cfg)
        p["lnx"] = L.init_rmsnorm(cfg.d_model, cfg.p_dtype)
    elif mixer == "ssm":
        p["ssm"] = L.init_mamba2(ks[0], cfg)
    elif mixer == "rglru":
        p["rglru"] = L.init_rglru(ks[0], cfg)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if ffn == "dense":
        p["ln2"] = L.init_rmsnorm(cfg.d_model, cfg.p_dtype)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif ffn == "moe":
        p["ln2"] = L.init_rmsnorm(cfg.d_model, cfg.p_dtype)
        p["moe"] = L.init_moe(ks[2], cfg)
    elif ffn != "none":
        raise ValueError(f"unknown ffn {ffn!r}")
    return p


def _init_stack(key, pattern, n_blocks, n_rem, cfg) -> PyTree:
    """Stacked params for scanned repeats + unrolled remainder."""
    kb, kr = jax.random.split(key)
    blocks = {}
    for j, kind in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(kb, j), max(n_blocks, 1))
        if n_blocks > 0:
            stacked = jax.vmap(lambda k: _init_block(k, kind, cfg))(keys)
            blocks[f"p{j}"] = stacked
    rem = tuple(
        _init_block(jax.random.fold_in(kr, i), pattern[i], cfg) for i in range(n_rem)
    )
    return {"blocks": blocks, "rem": rem}


def init_params(key, cfg) -> PyTree:
    ks = jax.random.split(key, 6)
    params: dict = {
        "embed": L._init_dense(ks[0], (cfg.padded_vocab, cfg.d_model), cfg.p_dtype, scale=0.02),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "decoder": _init_stack(ks[1], cfg.pattern, cfg.n_scan_blocks, cfg.n_rem_layers, cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._init_dense(
            ks[2], (cfg.d_model, cfg.padded_vocab), cfg.p_dtype, scale=0.02)
    if cfg.family == "encdec":
        enc_pattern = ("encattn:dense",)
        params["encoder"] = _init_stack(ks[3], enc_pattern, cfg.enc_layers, 0, cfg)
        params["enc_norm"] = L.init_rmsnorm(cfg.d_model, cfg.p_dtype)
        # frontend STUB: input_specs provides frame embeddings already at d_model
    if cfg.family == "vlm":
        params["patch_proj"] = L._init_dense(ks[4], (cfg.d_model, cfg.d_model), cfg.p_dtype)
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill) block application
# ---------------------------------------------------------------------------

def _apply_block(p, kind, x, positions, cfg, enc_out=None, collect_cache=False):
    """Returns (x, aux_loss, cache_entry_or_None)."""
    mixer, ffn = _parse_kind(kind)
    aux = jnp.zeros((), F32)
    cache_entry = None

    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if mixer in ("attn", "swa"):
        if cfg.attn_seq_shard:
            from jax.sharding import PartitionSpec as _P

            h = jax.lax.with_sharding_constraint(h, _P(None, "model", None))
        q, k, v = L.attn_qkv(p["attn"], h, positions, cfg)
        window = cfg.window if mixer == "swa" else None
        out = L.causal_attention(q, k, v, window=window, q_block=cfg.q_block)
        if cfg.attn_seq_shard:
            from jax.sharding import PartitionSpec as _P

            out = jax.lax.with_sharding_constraint(
                out, _P(None, "model", None, None))
        x = x + L.attn_proj_out(p["attn"], out)
        if collect_cache:
            if mixer == "swa":
                w = min(cfg.window, k.shape[1])
                cache_entry = {"k": k[:, -w:], "v": v[:, -w:]}
            else:
                cache_entry = {"k": k, "v": v}
    elif mixer == "encattn":
        q, k, v = L.attn_qkv(p["attn"], h, positions, cfg)
        out = L.full_attention(q, k, v)
        x = x + L.attn_proj_out(p["attn"], out)
    elif mixer == "xattn":
        q, k, v = L.attn_qkv(p["attn"], h, positions, cfg)
        out = L.causal_attention(q, k, v, q_block=cfg.q_block)
        x = x + L.attn_proj_out(p["attn"], out)
        hx = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
        B, Se, _ = enc_out.shape
        qx = (hx @ p["xattn"]["wq"].astype(hx.dtype)).reshape(
            B, hx.shape[1], cfg.n_heads, cfg.hd
        )
        kx = (enc_out @ p["xattn"]["wk"].astype(hx.dtype)).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
        vx = (enc_out @ p["xattn"]["wv"].astype(hx.dtype)).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
        out = L.full_attention(qx, kx, vx)
        x = x + L.attn_proj_out(p["xattn"], out)
        if collect_cache:
            cache_entry = {"k": k, "v": v, "kx": kx, "vx": vx}
    elif mixer == "ssm":
        out = L.mamba2_apply(p["ssm"], h, cfg)
        x = x + out
        if collect_cache:
            cache_entry = "ssm_final"  # filled by caller (needs final state)
    elif mixer == "rglru":
        out = L.rglru_apply(p["rglru"], h, cfg)
        x = x + out
        if collect_cache:
            cache_entry = "rglru_final"

    if ffn == "dense":
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    elif ffn == "moe":
        out, moe_aux = L.moe_apply(p["moe"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        x = x + out
        aux = aux + moe_aux
    if cfg.attn_seq_shard and x.ndim == 3:
        # Megatron-style sequence parallelism on the residual stream: the
        # row-parallel MLP output becomes a reduce-scatter (1x payload)
        # instead of an all-reduce (2x), and activations shard 16-way.
        from jax.sharding import PartitionSpec as _P

        x = jax.lax.with_sharding_constraint(x, _P(None, "model", None))
    return x, aux, cache_entry


def _run_stack(stack, pattern, x, positions, cfg, enc_out=None, remat=True,
               unroll=False, remat_policy="full"):
    """Scanned pattern repeats + unrolled remainder. Returns (x, aux_sum).

    ``unroll=True`` replaces the layer scan with a python loop — used by the
    roofline pass, because XLA's cost_analysis counts while-loop bodies once
    regardless of trip count.  Numerically identical.
    """

    def body(carry, block_params):
        x, aux = carry
        for j, kind in enumerate(pattern):
            if f"p{j}" not in block_params:
                continue
            x, a, _ = _apply_block(block_params[f"p{j}"], kind, x, positions, cfg, enc_out)
            aux = aux + a
        return (x, aux), None

    if remat and remat_policy == "dots":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat:
        body_fn = jax.checkpoint(body)
    else:
        body_fn = body
    aux0 = jnp.zeros((), F32)
    if stack["blocks"]:
        if unroll:
            nb = jax.tree.leaves(stack["blocks"])[0].shape[0]
            carry = (x, aux0)
            for i in range(nb):
                bp = jax.tree.map(lambda a: a[i], stack["blocks"])
                carry, _ = body_fn(carry, bp)
            x, aux = carry
        else:
            (x, aux), _ = jax.lax.scan(body_fn, (x, aux0), stack["blocks"])
    else:
        aux = aux0
    for i, p in enumerate(stack["rem"]):
        x, a, _ = _apply_block(p, pattern[i], x, positions, cfg, enc_out)
        aux = aux + a
    return x, aux


def _embed(params, tokens, cfg):
    e = params["embed"][tokens].astype(cfg.act_dtype)
    return e * math.sqrt(cfg.d_model)


def _encode(params, frames, cfg, remat=True, unroll=False):
    """Whisper-style encoder over (stub) frame embeddings (B, enc_len, d)."""
    x = frames.astype(cfg.act_dtype)
    positions = jnp.arange(x.shape[1])
    x, _ = _run_stack(params["encoder"], ("encattn:dense",), x, positions, cfg,
                      remat=remat, unroll=unroll)
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def hidden_states(params, batch, cfg, remat=True, unroll=False,
                  remat_policy="full"):
    """Full forward to final hidden states. Returns (h, aux, n_prefix).

    ``n_prefix`` = number of non-text positions (VLM patches) to exclude
    from the LM loss.
    """
    enc_out = None
    n_prefix = 0
    if cfg.family == "encdec":
        enc_out = _encode(params, batch["frames"], cfg, remat=remat, unroll=unroll)
        x = _embed(params, batch["tokens"], cfg)
    elif cfg.family == "vlm":
        patches = (batch["patches"].astype(cfg.act_dtype)
                   @ params["patch_proj"].astype(cfg.act_dtype))
        text = _embed(params, batch["tokens"], cfg)
        x = jnp.concatenate([patches, text], axis=1)
        n_prefix = patches.shape[1]
    else:
        x = _embed(params, batch["tokens"], cfg)

    positions = jnp.arange(x.shape[1])
    x, aux = _run_stack(params["decoder"], cfg.pattern, x, positions, cfg,
                        enc_out, remat, unroll, remat_policy)
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), aux, n_prefix


def _logits(params, h, cfg):
    if cfg.tie_embeddings:
        return h.astype(F32) @ params["embed"].astype(F32).T
    return h.astype(F32) @ params["lm_head"].astype(F32)


def loss_fn(params, batch, cfg, remat: bool = True, unroll: bool = False,
            remat_policy: str = "full") -> jnp.ndarray:
    """Next-token CE, chunked over the sequence to bound logits memory."""
    h, aux, n_prefix = hidden_states(params, batch, cfg, remat=remat,
                                     unroll=unroll, remat_policy=remat_policy)
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    h_text = h[:, n_prefix:]

    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1
    )
    mask = jnp.concatenate(
        [jnp.ones((B, S_text - 1), F32), jnp.zeros((B, 1), F32)], axis=1
    )

    chunk = min(CE_CHUNK, S_text)
    n_chunks = -(-S_text // chunk)
    pad = n_chunks * chunk - S_text
    if pad:
        h_text = jnp.pad(h_text, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))

    hc = h_text.reshape(B, n_chunks, chunk, -1).swapaxes(0, 1)
    tc = targets.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    def ce_chunk(carry, inp):
        hcc, tcc, mcc = inp
        logits = _logits(params, hcc, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tcc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mcc
        return carry + nll.sum(), None

    total, _ = jax.lax.scan(ce_chunk, jnp.zeros((), F32), (hc, tc, mc))
    loss = total / jnp.maximum(mask.sum(), 1.0)
    return loss + MOE_AUX_COEF * aux


# ---------------------------------------------------------------------------
# KV cache: init / prefill / decode
# ---------------------------------------------------------------------------

def _init_block_cache(kind, cfg, batch, max_len, dtype):
    mixer, _ = _parse_kind(kind)
    kvh, hd = cfg.n_kv_heads, cfg.hd
    if mixer == "attn":
        return {
            "k": jnp.zeros((batch, max_len, kvh, hd), dtype),
            "v": jnp.zeros((batch, max_len, kvh, hd), dtype),
        }
    if mixer == "swa":
        w = min(cfg.window, max_len)
        return {
            "k": jnp.zeros((batch, w, kvh, hd), dtype),
            "v": jnp.zeros((batch, w, kvh, hd), dtype),
        }
    if mixer == "xattn":
        return {
            "k": jnp.zeros((batch, max_len, kvh, hd), dtype),
            "v": jnp.zeros((batch, max_len, kvh, hd), dtype),
            "kx": jnp.zeros((batch, cfg.enc_len, kvh, hd), dtype),
            "vx": jnp.zeros((batch, cfg.enc_len, kvh, hd), dtype),
        }
    if mixer == "ssm":
        return L.mamba2_init_cache(cfg, batch, dtype)
    if mixer == "rglru":
        return L.rglru_init_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_len: int, dtype=None) -> PyTree:
    dtype = dtype or cfg.act_dtype
    nb = cfg.n_scan_blocks
    blocks = {}
    for j, kind in enumerate(cfg.pattern):
        if nb > 0:
            one = _init_block_cache(kind, cfg, batch, max_len, dtype)
            blocks[f"p{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (nb,) + a.shape), one
            )
    rem = tuple(
        _init_block_cache(cfg.pattern[i], cfg, batch, max_len, dtype)
        for i in range(cfg.n_rem_layers)
    )
    return {"blocks": blocks, "rem": rem}


def _decode_block(p, kind, cache, x, pos, cfg, max_len):
    """One-token step through one block. x: (B,1,d). Returns (x, new_cache)."""
    mixer, ffn = _parse_kind(kind)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    B = x.shape[0]

    if mixer in ("attn", "swa", "xattn"):
        q, k, v = L.attn_qkv(p["attn"], h, pos[None], cfg)  # rope at abs pos
        if mixer == "swa":
            w = cache["k"].shape[1]
            slot = pos % w
            new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
            new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
            idx = jnp.arange(w)
            slot_pos = idx + w * ((pos - idx) // w)        # latest pos = i (mod w)
            valid = slot_pos >= 0
        else:
            new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
            new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
            valid = jnp.arange(new_k.shape[1]) <= pos
        out = L.decode_attention(q, new_k, new_v, valid)
        x = x + L.attn_proj_out(p["attn"], out)
        new_cache = dict(cache, k=new_k, v=new_v)
        if mixer == "xattn":
            hx = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
            qx = (hx @ p["xattn"]["wq"].astype(hx.dtype)).reshape(B, 1, cfg.n_heads, cfg.hd)
            outx = L.decode_attention(
                qx, cache["kx"], cache["vx"], jnp.ones((cache["kx"].shape[1],), bool)
            )
            x = x + L.attn_proj_out(p["xattn"], outx)
    elif mixer == "ssm":
        out, new_cache = L.mamba2_decode(p["ssm"], cache, h[:, 0], cfg)
        x = x + out[:, None]
    elif mixer == "rglru":
        out, new_cache = L.rglru_decode(p["rglru"], cache, h[:, 0], cfg)
        x = x + out[:, None]
    else:
        raise ValueError(kind)

    if ffn == "dense":
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    elif ffn == "moe":
        out, _ = L.moe_apply(p["moe"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        x = x + out
    return x, new_cache


def decode_step(params, cache, tokens, pos, cfg, unroll: bool = False):
    """tokens: (B,) int32; pos: scalar int32. Returns (logits (B,V), cache)."""
    x = _embed(params, tokens[:, None], cfg)
    max_len = None

    def body(x, inp):
        block_params, block_cache = inp
        new_caches = {}
        for j, kind in enumerate(cfg.pattern):
            key = f"p{j}"
            if key not in block_params:
                continue
            x, new_caches[key] = _decode_block(
                block_params[key], kind, block_cache[key], x, pos, cfg, max_len
            )
        return x, new_caches

    new_cache = {"blocks": {}, "rem": []}
    if params["decoder"]["blocks"]:
        if unroll:
            nb = jax.tree.leaves(params["decoder"]["blocks"])[0].shape[0]
            ys = []
            for i in range(nb):
                bp = jax.tree.map(lambda a: a[i], params["decoder"]["blocks"])
                bc = jax.tree.map(lambda a: a[i], cache["blocks"])
                x, nc = body(x, (bp, bc))
                ys.append(nc)
            new_cache["blocks"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *ys) if ys else {}
        else:
            x, new_cache["blocks"] = jax.lax.scan(
                body, x, (params["decoder"]["blocks"], cache["blocks"])
            )
    for i, p in enumerate(params["decoder"]["rem"]):
        x, nc = _decode_block(p, cfg.pattern[i], cache["rem"][i], x, pos, cfg, max_len)
        new_cache["rem"].append(nc)
    new_cache["rem"] = tuple(new_cache["rem"])

    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, h, cfg)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill: forward over a prompt, building the cache.
# ---------------------------------------------------------------------------

def _prefill_block_cache(p, kind, x, positions, cfg, enc_out):
    """Apply block and build its cache entry. Returns (x, cache_entry)."""
    mixer, _ = _parse_kind(kind)
    h_in = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if mixer == "ssm":
        # rerun projections to recover final state (single extra state pass)
        x2, _, _ = _apply_block(p, kind, x, positions, cfg, enc_out)
        state = _mamba2_final_state(p["ssm"], h_in, cfg)
        return x2, state
    if mixer == "rglru":
        x2, _, _ = _apply_block(p, kind, x, positions, cfg, enc_out)
        state = _rglru_final_state(p["rglru"], h_in, cfg)
        return x2, state
    x2, _, entry = _apply_block(p, kind, x, positions, cfg, enc_out, collect_cache=True)
    return x2, entry


def _mamba2_final_state(p, h, cfg):
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = h @ p["in_proj"].astype(h.dtype)
    z, xs, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], -1)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(L.conv1d_apply(p["conv"], conv_in))
    xs2, Bm2, Cm2 = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])
    A = jnp.exp(p["A_log"])
    dA = jnp.exp(-A[None, None] * dt)                      # (B,S,H)
    xh = xs2.reshape(*xs2.shape[:2], H, P).astype(F32)
    dBx = jnp.einsum("bsh,bsn,bshp->bshpn", dt, Bm2.astype(F32), xh)

    def step(state, inp):
        dAs, dBxs = inp
        return state * dAs[..., None, None] + dBxs, None

    state0 = jnp.zeros((h.shape[0], H, P, N), F32)
    state, _ = jax.lax.scan(
        step, state0, (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0))
    )
    conv_tail = conv_in[:, -(cfg.conv_width - 1):]
    return {"state": state, "conv": conv_tail}


def _rglru_final_state(p, h, cfg):
    xr = h @ p["in_x"].astype(h.dtype)
    xc = L.conv1d_apply(p["conv"], xr)
    a, b = L._rglru_coeffs(p, xc)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    conv_tail = xr[:, -(cfg.conv_width - 1):]
    return {"h": hs[:, -1], "conv": conv_tail}


def prefill(params, batch, cfg, remat: bool = True, unroll: bool = False):
    """Forward over prompt tokens; returns (last-token logits, cache)."""
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, batch["frames"], cfg, remat=remat)
        x = _embed(params, batch["tokens"], cfg)
    elif cfg.family == "vlm":
        patches = (batch["patches"].astype(cfg.act_dtype)
                   @ params["patch_proj"].astype(cfg.act_dtype))
        text = _embed(params, batch["tokens"], cfg)
        x = jnp.concatenate([patches, text], axis=1)
    else:
        x = _embed(params, batch["tokens"], cfg)

    positions = jnp.arange(x.shape[1])

    def body(x, block_params):
        caches = {}
        for j, kind in enumerate(cfg.pattern):
            key = f"p{j}"
            if key not in block_params:
                continue
            x, caches[key] = _prefill_block_cache(
                block_params[key], kind, x, positions, cfg, enc_out
            )
        return x, caches

    body_fn = jax.checkpoint(body) if remat else body
    cache = {"blocks": {}, "rem": []}
    if params["decoder"]["blocks"]:
        if unroll:
            nb = jax.tree.leaves(params["decoder"]["blocks"])[0].shape[0]
            ys = []
            for i in range(nb):
                bp = jax.tree.map(lambda a: a[i], params["decoder"]["blocks"])
                x, c = body_fn(x, bp)
                ys.append(c)
            cache["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *ys) if ys else {}
        else:
            x, cache["blocks"] = jax.lax.scan(body_fn, x, params["decoder"]["blocks"])
    for i, p in enumerate(params["decoder"]["rem"]):
        x, entry = _prefill_block_cache(p, cfg.pattern[i], x, positions, cfg, enc_out)
        cache["rem"].append(entry)
    cache["rem"] = tuple(cache["rem"])

    h = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = _logits(params, h, cfg)[:, 0]
    return logits, cache
