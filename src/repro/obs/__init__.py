"""Observability: on-device metrics, structured run sinks, phase tracing,
and the comm ledger (docs/observability.md).

Layout:

  * ``metrics``   — the on-device metric pack computed INSIDE the jitted
                    outer step (pure jnp; safe to import from core).
  * ``sinks``     — per-run directory: manifest.json / events.jsonl /
                    scalars.csv (host-side only).
  * ``tracing``   — wall-time spans, ``jax.profiler.trace`` windows,
                    device memory stats.
  * ``ledger``    — observed (compiled-HLO) vs predicted (analytic model)
                    communication bytes.
  * ``summarize`` — ``python -m repro.obs summarize <run_dir>`` CLI.
"""

from repro.obs.metrics import (
    IDX,
    METRIC_NAMES,
    N_METRICS,
    finish_pack,
    loss_stats,
    minimal_pack,
    tree_stat_sums,
)
from repro.obs.sinks import RunWriter, build_manifest, read_run
from repro.obs.summarize import summarize_run

__all__ = [
    "IDX",
    "METRIC_NAMES",
    "N_METRICS",
    "RunWriter",
    "build_manifest",
    "finish_pack",
    "loss_stats",
    "minimal_pack",
    "read_run",
    "summarize_run",
    "tree_stat_sums",
]
