"""``python -m repro.obs`` — run-directory CLI (docs/observability.md).

  summarize <run_dir> [<run_dir_b>]
      Print a report for one run — scalar trajectory, per-phase spans,
      observed-vs-predicted comm bytes, throughput — or a scalar diff
      when a second run directory is given.  ``--json`` emits the
      machine-readable summary instead.

Pure host code: no jax import, safe to run on a box without devices.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _cmd_summarize(args: argparse.Namespace) -> int:
    from repro.obs.summarize import diff, render, summarize_run

    for d in filter(None, (args.run_dir, args.run_dir_b)):
        if not os.path.isdir(d):
            print(f"not a run directory: {d}", file=sys.stderr)
            return 2

    a = summarize_run(args.run_dir)
    if args.run_dir_b:
        b = summarize_run(args.run_dir_b)
        if args.json:
            print(json.dumps({"a": a, "b": b}, indent=2))
        else:
            print(diff(a, b))
        return 0
    if args.json:
        print(json.dumps(a, indent=2))
    else:
        print(render(a))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)

    ap_sum = sub.add_parser("summarize", help="summarize / diff run dirs")
    ap_sum.add_argument("run_dir")
    ap_sum.add_argument("run_dir_b", nargs="?", default=None,
                        help="second run dir: print a scalar diff instead")
    ap_sum.add_argument("--json", action="store_true")
    ap_sum.set_defaults(fn=_cmd_summarize)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
