"""Comm ledger: observed (compiled-HLO) vs predicted (analytic) collective
bytes for the training step (docs/observability.md).

The analytic model (``benchmarks.comm``) predicts what Algorithm 1 *should*
communicate per outer step; ``repro.analysis.hlo_audit`` already parses
what the compiled program *actually* contains.  The ledger joins the two
at trainer startup: it lowers the live jitted step — same function, same
argument shardings, same mesh — parses its collectives, and emits an
``observed vs predicted`` record into the run's event stream, so every run
directory carries the evidence behind the paper's 'Com. red.' column.

Observed bytes are HLO result-shape payload bytes (what the auditor
bounds); the analytic *wire* figure is ~2x payload under the ring model,
and the ledger reports both so the summarize CLI can show the ratio
explicitly rather than bake the factor in.

The probe lowering happens once, before any sanitizer context is armed
(it is itself a compile, and must not trip the steady-state recompilation
counter), and on a degenerate single-device mesh the partitioner compiles
zero collectives — the record says so instead of reporting a fake match.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

PyTree = Any


def compile_time_ledger(
    step_fn: Any,
    args: Sequence[Any],
    *,
    params: PyTree,
    algo: str,
    tau: int,
    phase: str,
    mesh: Optional[Any] = None,
    name: str = "outer_step",
) -> Dict[str, Any]:
    """Lower ``step_fn(*args)`` and join its collectives with the model.

    ``params``: the global buffer pytree the phase moves (x0) — payload
    bytes use the reduce dtype floor of 4 B/elem, matching the auditor.
    ``phase``: one of ``benchmarks.comm.PHASES``.
    """
    import jax

    from benchmarks.comm import (GATHER_CLASS, PHASES, REDUCE_CLASS,
                                 wire_bytes_for_payload)
    from repro.analysis.hlo_audit import parse_collectives

    if phase not in PHASES:
        raise ValueError(f"phase must be one of {PHASES}, got {phase!r}")

    # distinct jit wrapper + distinct __name__, so this compile is never
    # confused with the trainer's own train_step by the recompilation counter
    def ledger_probe(*a):
        return step_fn(*a)

    text = jax.jit(ledger_probe).lower(*args).compile().as_text()
    ops = parse_collectives(text)

    leaves = jax.tree.leaves(params)
    payload = sum(l.size * max(4, getattr(l.dtype, "itemsize", 4))
                  for l in leaves)
    wire, rounds = wire_bytes_for_payload(payload, algo, tau)
    pred_reduce = payload if phase != "local" else 0
    pred_gather = payload if phase == "global_zero" else 0

    obs_reduce = sum(o.bytes for o in ops if o.kind in REDUCE_CLASS)
    obs_gather = sum(o.bytes for o in ops if o.kind in GATHER_CLASS)
    other = [o for o in ops
             if o.kind not in REDUCE_CLASS and o.kind not in GATHER_CLASS]

    mesh_devices = 1
    if mesh is not None:
        mesh_devices = 1
        for v in mesh.shape.values():
            mesh_devices *= int(v)
    degenerate = mesh_devices <= 1

    def _ratio(obs: int, pred: int) -> Optional[float]:
        if pred <= 0 or degenerate:
            return None
        return obs / pred

    return {
        "name": name,
        "phase": phase,
        "algo": algo,
        "tau": int(tau),
        "n_param_leaves": len(leaves),
        "mesh_devices": mesh_devices,
        "degenerate_mesh": degenerate,
        "predicted": {
            "payload_bytes": int(payload),
            "reduce_bytes": int(pred_reduce),
            "gather_bytes": int(pred_gather),
            "wire_bytes_per_outer": int(wire),
            "comm_rounds_per_outer": int(rounds),
        },
        "observed": {
            "reduce_ops": sum(1 for o in ops if o.kind in REDUCE_CLASS),
            "gather_ops": sum(1 for o in ops if o.kind in GATHER_CLASS),
            "other_ops": len(other),
            "other_kinds": sorted({o.kind for o in other}),
            "reduce_bytes": int(obs_reduce),
            "gather_bytes": int(obs_gather),
        },
        "ratio": {
            "reduce": _ratio(obs_reduce, pred_reduce),
            "gather": _ratio(obs_gather, pred_gather),
        },
    }
