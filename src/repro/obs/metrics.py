"""On-device metric pack for the DSM outer step (docs/observability.md).

The paper's claims are about optimizer *dynamics* — sign momentum built
from local-step differences — so the quantities worth watching are the l1 /
l2 statistics that govern sign methods (Bernstein et al., 2018: signSGD's
convergence is controlled by the gradient density phi = ||g||_1^2 /
(d * ||g||_2^2)) and the alignment between the momentum ``m`` and each
round's pseudo-gradient ``Delta = (x_0 - x_tau) / gamma``.

Everything here is computed INSIDE the jitted outer step and returned as
one stacked ``(N_METRICS,)`` f32 array (``metrics["pack"]``), so
instrumentation adds **zero host syncs** — the trainer keeps the packs on
device and fetches them asynchronously at log / eval / checkpoint points.
The collective cost is bounded by construction:

  * ``loss_stats`` folds the three per-worker loss statistics into a
    single stacked reduction, so a worker-sharded loss matrix lowers to
    ONE all-reduce (instead of one per statistic);
  * the global-state sums (``stat_sums_block``) are plain elementwise
    sums — collective-free on replicated buffers, and the ZeRO-sharded
    path wraps them in one psum of the stacked partials
    (``repro.distributed.zero.sharded_stat_sums``).

Both fit inside the ``n_metric_reductions = 2`` scalar-reduction allowance
the audited per-phase budgets already carry (benchmarks/comm.py), which is
how ``python -m repro.analysis audit`` proves the instrumented step keeps
the paper's collective budget unchanged.

This module is jit-reachable: no host reads, no traced-value branches.
Host-side decoding (pack -> dict) lives in ``repro.obs.sinks``.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

PyTree = Any

# Pack layout.  Definitions (d = number of global parameters):
#   loss          mean local-step train loss over (tau, W)
#   last_loss     mean loss of the LAST local step (end-of-round iterate)
#   gamma         inner learning rate of the round
#   pg_l1         ||Delta||_1, Delta = (x_0 - x_tau)/gamma (pseudo-gradient)
#   pg_l2         ||Delta||_2
#   pg_density    ||Delta||_1^2 / (d * ||Delta||_2^2)  in (0, 1]; the
#                 signSGD density phi (1 = uniform, 1/d = one-hot)
#   sign_agree    (1/d) sum_j 1[sign(m_j) * sign(Delta_j) > 0]  — fraction
#                 of coordinates where the momentum and the round's
#                 accumulated difference vote the same sign (0 while m = 0)
#   m_l1          ||m||_1 (momentum mass)
#   update_cos    cos(u, m), u = beta1*m + (1-beta1)*Delta — the round's
#                 pre-sign update direction vs the momentum carried in
#                 from previous rounds
#   worker_spread std over workers of the per-worker mean loss
#   survivor_frac fraction of usable worker contributions (1.0 dense)
#   guard_ok      1.0 accepted / 0.0 rejected (set by the guard wrapper)
METRIC_NAMES = (
    "loss",
    "last_loss",
    "gamma",
    "pg_l1",
    "pg_l2",
    "pg_density",
    "sign_agree",
    "m_l1",
    "update_cos",
    "worker_spread",
    "survivor_frac",
    "guard_ok",
)
IDX = {name: i for i, name in enumerate(METRIC_NAMES)}
N_METRICS = len(METRIC_NAMES)

# Raw sums the pack is finished from; every entry is a plain elementwise
# sum so shard-local partials combine by addition (one psum when sharded).
STAT_SUMS = ("pg_l1", "pg_sq", "m_l1", "sign_agree_count", "u_dot_m",
             "u_sq", "m_sq")
N_STAT_SUMS = len(STAT_SUMS)

_EPS = 1e-12


def loss_stats(losses: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """``(loss, last_loss, worker_spread)`` from the ``(tau, W)`` per-worker
    loss matrix of one round.

    The three statistics are stacked into a single ``(3, W)`` array before
    the worker reduction, so when ``losses`` is worker-sharded (the
    device-parallel local phase) the whole bundle lowers to ONE all-reduce
    — it rides the metric-scalar allowance of the audited budgets.
    """
    per_worker = losses.mean(axis=0)                     # (W,) shard-local
    bundle = jnp.stack([per_worker, losses[-1], per_worker * per_worker])
    s = bundle.mean(axis=1)                              # the ONE reduction
    spread = jnp.sqrt(jnp.maximum(s[2] - s[0] * s[0], 0.0))
    return s[0], s[1], spread


def stat_sums_block(
    x0_leaves: Sequence[jnp.ndarray],
    m_leaves: Sequence[jnp.ndarray],
    xt_leaves: Sequence[jnp.ndarray],
    gamma: jnp.ndarray,
    beta1: float,
) -> jnp.ndarray:
    """``(N_STAT_SUMS,)`` f32 sums over the given leaf blocks.

    Pure elementwise + local sums: on replicated buffers this compiles to
    zero collectives; the ZeRO-sharded path calls it per-shard inside a
    shard_map and psums the stacked result once.
    """
    g = jnp.asarray(gamma, jnp.float32)
    b1 = jnp.float32(beta1)
    tot = jnp.zeros((N_STAT_SUMS,), jnp.float32)
    for x0l, ml, xtl in zip(x0_leaves, m_leaves, xt_leaves):
        x0f = x0l.astype(jnp.float32)
        mf = ml.astype(jnp.float32)
        delta = (x0f - xtl.astype(jnp.float32)) / g
        u = b1 * mf + (1.0 - b1) * delta
        agree = (jnp.sign(mf) * jnp.sign(delta)) > 0
        tot = tot + jnp.stack([
            jnp.abs(delta).sum(),
            (delta * delta).sum(),
            jnp.abs(mf).sum(),
            agree.sum().astype(jnp.float32),
            (u * mf).sum(),
            (u * u).sum(),
            (mf * mf).sum(),
        ])
    return tot


def tree_stat_sums(x0: PyTree, m: PyTree, x_tau: PyTree, gamma, beta1: float) -> jnp.ndarray:
    """Whole-tree ``stat_sums_block`` (replicated / dense path)."""
    return stat_sums_block(
        jax.tree.leaves(x0), jax.tree.leaves(m), jax.tree.leaves(x_tau),
        gamma, beta1,
    )


def n_elements(tree: PyTree) -> int:
    return sum(l.size for l in jax.tree.leaves(tree))


def finish_pack(
    *,
    loss,
    last_loss,
    gamma,
    worker_spread,
    stat_sums: jnp.ndarray,
    n_elems: int,
    survivor_frac=None,
) -> jnp.ndarray:
    """Assemble the ``(N_METRICS,)`` f32 pack from the raw sums."""
    l1, sq, m_l1, agree, u_dot_m, u_sq, m_sq = (stat_sums[i] for i in range(N_STAT_SUMS))
    pg_l2 = jnp.sqrt(sq)
    density = (l1 * l1) / (n_elems * sq + _EPS)
    cos = u_dot_m / (jnp.sqrt(u_sq) * jnp.sqrt(m_sq) + _EPS)
    sf = (jnp.float32(1.0) if survivor_frac is None
          else jnp.asarray(survivor_frac, jnp.float32))
    return jnp.stack([
        jnp.asarray(loss, jnp.float32),
        jnp.asarray(last_loss, jnp.float32),
        jnp.asarray(gamma, jnp.float32),
        l1,
        pg_l2,
        density,
        agree / n_elems,
        m_l1,
        cos,
        jnp.asarray(worker_spread, jnp.float32),
        sf,
        jnp.float32(1.0),
    ])


def minimal_pack(loss, gamma: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Pack for algorithms without global-state instrumentation (the
    baselines): loss (+ gamma when known), NaN for the DSM-only entries."""
    vals = [jnp.float32(jnp.nan)] * N_METRICS
    vals[IDX["loss"]] = jnp.asarray(loss, jnp.float32)
    if gamma is not None:
        vals[IDX["gamma"]] = jnp.asarray(gamma, jnp.float32)
    vals[IDX["survivor_frac"]] = jnp.float32(1.0)
    vals[IDX["guard_ok"]] = jnp.float32(1.0)
    return jnp.stack(vals)


def set_guard_flag(pack: jnp.ndarray, ok) -> jnp.ndarray:
    """Record the guard verdict in the pack (device-side select)."""
    return pack.at[IDX["guard_ok"]].set(jnp.asarray(ok, jnp.float32))
