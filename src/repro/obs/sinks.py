"""Structured run sinks: per-run directory with a manifest, a JSONL event
stream, and a CSV scalar table (docs/observability.md).

Run-dir layout::

    <run_dir>/
      manifest.json   # config, mesh shape, dtypes, jax version, git sha
      events.jsonl    # one JSON object per line: spans, comm ledger,
                      # checkpoints, eval points, run lifecycle
      scalars.csv     # step,<METRIC_NAMES...> — one row per flushed pack

This module is host-side only — it converts device packs to floats — so it
must never be imported from jit-reachable code (``repro.obs.metrics`` is
the jit-safe half).  Writers append with line-buffered handles so a run
killed mid-flight still leaves a readable prefix, and resumed runs reopen
the same files in append mode without rewriting history.
"""

from __future__ import annotations

import csv
import dataclasses
import json
import os
import subprocess
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.metrics import METRIC_NAMES

SCALAR_HEADER = ("step",) + METRIC_NAMES


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd, capture_output=True, text=True, timeout=5,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def _jsonable(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {k: _jsonable(v) for k, v in dataclasses.asdict(obj).items()}
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer)):
        return obj.item()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return str(obj)


def build_manifest(
    *,
    run_name: str,
    settings: Any = None,
    model_cfg: Any = None,
    mesh: Any = None,
    extra: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Everything needed to identify / reproduce a run, as plain JSON."""
    import jax

    man: Dict[str, Any] = {
        "run_name": run_name,
        "created_unix": time.time(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "git_sha": git_sha(),
        "metric_names": list(METRIC_NAMES),
    }
    if mesh is not None:
        man["mesh"] = {
            "axis_names": list(mesh.axis_names),
            "shape": {str(k): int(v) for k, v in mesh.shape.items()},
        }
    if settings is not None:
        man["settings"] = _jsonable(settings)
    if model_cfg is not None:
        man["model_cfg"] = _jsonable(model_cfg)
    if extra:
        man["extra"] = _jsonable(extra)
    return man


def pack_to_dict(pack) -> Dict[str, float]:
    """Decode a fetched ``(N_METRICS,)`` pack into ``{name: float}``.

    Host-side by design: call it only on packs already pulled off device
    (``jax.device_get`` / ``np.asarray``), never inside traced code.
    """
    arr = np.asarray(pack, dtype=np.float64).reshape(-1)
    if arr.shape[0] != len(METRIC_NAMES):
        raise ValueError(
            f"pack has {arr.shape[0]} entries, expected {len(METRIC_NAMES)}"
        )
    return {name: float(v) for name, v in zip(METRIC_NAMES, arr)}


class RunWriter:
    """Append-only writer for one run directory."""

    def __init__(self, run_dir: str, manifest: Optional[Dict[str, Any]] = None,
                 resume: bool = False):
        self.run_dir = run_dir
        os.makedirs(run_dir, exist_ok=True)
        self._events_path = os.path.join(run_dir, "events.jsonl")
        self._scalars_path = os.path.join(run_dir, "scalars.csv")
        manifest_path = os.path.join(run_dir, "manifest.json")
        if manifest is not None and not (resume and os.path.exists(manifest_path)):
            tmp = manifest_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(_jsonable(manifest), f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, manifest_path)
        need_header = not (resume and os.path.exists(self._scalars_path)
                           and os.path.getsize(self._scalars_path) > 0)
        mode = "a" if resume else "w"
        self._events = open(self._events_path, mode, buffering=1)
        self._scalars = open(self._scalars_path, mode, buffering=1)
        self._csv = csv.writer(self._scalars)
        if need_header:
            self._csv.writerow(SCALAR_HEADER)
        self._closed = False

    # -- sinks ---------------------------------------------------------

    def event(self, kind: str, **fields: Any) -> None:
        rec = {"kind": kind, "wall": time.time()}
        rec.update(_jsonable(fields))
        self._events.write(json.dumps(rec) + "\n")

    def metrics_row(self, step: int, pack) -> Dict[str, float]:
        """Write one scalars.csv row; returns the decoded dict for reuse
        (e.g. the trainer's log line)."""
        d = pack_to_dict(pack)
        self._csv.writerow([int(step)] + [d[n] for n in METRIC_NAMES])
        return d

    def span(self, name: str, seconds: float, **fields: Any) -> None:
        self.event("span", name=name, seconds=float(seconds), **fields)

    def flush(self) -> None:
        if not self._closed:
            self._events.flush()
            self._scalars.flush()

    def close(self) -> None:
        if not self._closed:
            self._events.close()
            self._scalars.close()
            self._closed = True

    def __enter__(self) -> "RunWriter":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def read_run(run_dir: str) -> Tuple[Dict[str, Any], List[Dict[str, Any]],
                                    List[Dict[str, Any]]]:
    """Load ``(manifest, events, scalar_rows)`` from a run directory.

    Scalar rows come back as ``{"step": int, <name>: float, ...}``.
    Tolerates a truncated trailing JSONL line (killed run).
    """
    manifest_path = os.path.join(run_dir, "manifest.json")
    manifest: Dict[str, Any] = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    events: List[Dict[str, Any]] = []
    events_path = os.path.join(run_dir, "events.jsonl")
    if os.path.exists(events_path):
        with open(events_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    events.append(json.loads(line))
                except json.JSONDecodeError:
                    break  # truncated tail from a killed run

    rows: List[Dict[str, Any]] = []
    scalars_path = os.path.join(run_dir, "scalars.csv")
    if os.path.exists(scalars_path):
        with open(scalars_path) as f:
            reader = csv.DictReader(f)
            for raw in reader:
                try:
                    row: Dict[str, Any] = {"step": int(raw["step"])}
                    for name in reader.fieldnames or ():
                        if name != "step":
                            row[name] = float(raw[name])
                except (KeyError, TypeError, ValueError):
                    continue  # truncated / partial row
                rows.append(row)
    return manifest, events, rows
