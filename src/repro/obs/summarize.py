"""Summarize / diff run directories (``python -m repro.obs summarize``).

Reads the sinks written by ``repro.obs.sinks.RunWriter`` — manifest,
events.jsonl, scalars.csv — and renders a compact report: run identity,
scalar trajectory (first / last / best, including the sign-agreement and
density metrics the paper's dynamics story turns on), per-phase wall-time
spans, the observed-vs-predicted comm ledger, and throughput.  Pure host
code, no jax import.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

from repro.obs.sinks import read_run

# metrics the renderer highlights, in display order
_KEY_METRICS = ("loss", "pg_l1", "pg_l2", "pg_density", "sign_agree",
                "m_l1", "update_cos", "worker_spread", "survivor_frac")


def _finite(v: Any) -> Optional[float]:
    try:
        f = float(v)
    except (TypeError, ValueError):
        return None
    return f if math.isfinite(f) else None


def _dedupe_by_step(rows: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Keep the LAST row for each step (resumed runs re-log the boundary
    step), ordered by step."""
    by_step: Dict[int, Dict[str, Any]] = {}
    for row in rows:
        by_step[row["step"]] = row
    return [by_step[s] for s in sorted(by_step)]


def summarize_run(run_dir: str) -> Dict[str, Any]:
    """Machine-readable summary of one run directory."""
    manifest, events, rows = read_run(run_dir)
    rows = _dedupe_by_step(rows)

    scalars: Dict[str, Dict[str, Any]] = {}
    for name in _KEY_METRICS:
        series = [(r["step"], _finite(r.get(name))) for r in rows]
        series = [(s, v) for s, v in series if v is not None]
        if not series:
            continue
        vals = [v for _, v in series]
        best_step, best = min(series, key=lambda sv: sv[1])
        scalars[name] = {
            "first": vals[0],
            "last": vals[-1],
            "min": best,
            "min_step": best_step,
            "max": max(vals),
            "n": len(vals),
        }

    spans: Dict[str, Dict[str, float]] = {}
    for ev in events:
        if ev.get("kind") != "span":
            continue
        nm = ev.get("name", "?")
        sec = _finite(ev.get("seconds")) or 0.0
        n = int(ev.get("n", 1) or 1)
        agg = spans.setdefault(nm, {"seconds": 0.0, "count": 0})
        agg["seconds"] += sec
        agg["count"] += n
    for agg in spans.values():
        agg["ms_per"] = 1e3 * agg["seconds"] / max(agg["count"], 1)

    ledger = None
    finished = None
    resumes = 0
    for ev in events:
        kind = ev.get("kind")
        if kind == "comm_ledger":
            ledger = ev
        elif kind == "finished":
            finished = ev
        elif kind == "resumed":
            resumes += 1

    throughput: Dict[str, Any] = {}
    if finished is not None:
        for k in ("steps", "wall_s", "steps_per_s", "tokens", "tokens_per_s"):
            v = _finite(finished.get(k))
            if v is not None:
                throughput[k] = v

    return {
        "run_dir": run_dir,
        "run_name": manifest.get("run_name"),
        "git_sha": manifest.get("git_sha"),
        "jax_version": manifest.get("jax_version"),
        "backend": manifest.get("backend"),
        "mesh": manifest.get("mesh"),
        "algorithm": (manifest.get("settings") or {}).get("algorithm"),
        "steps_logged": len(rows),
        "first_step": rows[0]["step"] if rows else None,
        "last_step": rows[-1]["step"] if rows else None,
        "resumes": resumes,
        "scalars": scalars,
        "spans": spans,
        "comm_ledger": ledger,
        "throughput": throughput,
    }


def _fmt(v: Any, nd: int = 4) -> str:
    f = _finite(v)
    if f is None:
        return "-"
    if f != 0 and (abs(f) >= 1e5 or abs(f) < 1e-3):
        return f"{f:.3e}"
    return f"{f:.{nd}f}"


def _fmt_bytes(v: Any) -> str:
    f = _finite(v)
    if f is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(f) < 1024 or unit == "GiB":
            return f"{f:.1f} {unit}" if unit != "B" else f"{int(f)} B"
        f /= 1024
    return f"{f:.1f} GiB"


def render(summary: Dict[str, Any]) -> str:
    """Human-readable report for one summarized run."""
    lines: List[str] = []
    lines.append(f"run      {summary.get('run_name') or summary['run_dir']}")
    ident = []
    for key in ("algorithm", "backend", "jax_version"):
        if summary.get(key):
            ident.append(f"{key}={summary[key]}")
    if summary.get("git_sha"):
        ident.append(f"git={summary['git_sha'][:10]}")
    mesh = summary.get("mesh")
    if mesh:
        shape = "x".join(f"{k}:{v}" for k, v in (mesh.get("shape") or {}).items())
        ident.append(f"mesh={shape}")
    if ident:
        lines.append("         " + "  ".join(ident))
    span_rng = (summary.get("first_step"), summary.get("last_step"))
    lines.append(
        f"steps    {summary['steps_logged']} logged"
        + (f" (outer {span_rng[0]}..{span_rng[1]})" if span_rng[0] is not None else "")
        + (f", {summary['resumes']} resume(s)" if summary.get("resumes") else ""))

    if summary["scalars"]:
        lines.append("")
        lines.append(f"{'metric':<14}{'first':>12}{'last':>12}{'min':>12}  @step")
        for name in _KEY_METRICS:
            s = summary["scalars"].get(name)
            if not s:
                continue
            lines.append(
                f"{name:<14}{_fmt(s['first']):>12}{_fmt(s['last']):>12}"
                f"{_fmt(s['min']):>12}  {s['min_step']}")

    if summary["spans"]:
        lines.append("")
        lines.append(f"{'phase':<18}{'total s':>10}{'count':>8}{'ms/it':>10}")
        for name in sorted(summary["spans"]):
            agg = summary["spans"][name]
            lines.append(
                f"{name:<18}{agg['seconds']:>10.3f}{agg['count']:>8d}"
                f"{agg['ms_per']:>10.2f}")

    led = summary.get("comm_ledger")
    if led:
        pred, obs = led.get("predicted", {}), led.get("observed", {})
        lines.append("")
        lines.append(f"comm ledger ({led.get('phase')}, algo={led.get('algo')},"
                     f" tau={led.get('tau')})")
        for cls in ("reduce", "gather"):
            p, o = pred.get(f"{cls}_bytes"), obs.get(f"{cls}_bytes")
            r = (led.get("ratio") or {}).get(cls)
            lines.append(
                f"  {cls:<7} observed {_fmt_bytes(o):>11}  predicted"
                f" {_fmt_bytes(p):>11}  ratio {_fmt(r, 3)}")
        if led.get("degenerate_mesh"):
            lines.append("  (single-device mesh: the partitioner compiles no"
                         " collectives; ratios suppressed)")
        if pred.get("wire_bytes_per_outer") is not None:
            lines.append(
                f"  ring-model wire bytes/outer {_fmt_bytes(pred['wire_bytes_per_outer'])}"
                f" over {pred.get('comm_rounds_per_outer')} round(s)")

    tp = summary.get("throughput") or {}
    if tp:
        lines.append("")
        bits = []
        if "steps_per_s" in tp:
            bits.append(f"{tp['steps_per_s']:.3f} outer steps/s")
        if "tokens_per_s" in tp:
            bits.append(f"{tp['tokens_per_s']:.0f} tokens/s")
        if "wall_s" in tp:
            bits.append(f"{tp['wall_s']:.1f} s wall")
        lines.append("throughput  " + "  ".join(bits))
    return "\n".join(lines)


def diff(a: Dict[str, Any], b: Dict[str, Any]) -> str:
    """Side-by-side scalar/throughput comparison of two summaries."""
    lines: List[str] = []
    na = a.get("run_name") or a["run_dir"]
    nb = b.get("run_name") or b["run_dir"]
    lines.append(f"diff  A={na}  B={nb}")
    lines.append(f"{'metric (last)':<16}{'A':>12}{'B':>12}{'B-A':>12}")
    for name in _KEY_METRICS:
        sa, sb = a["scalars"].get(name), b["scalars"].get(name)
        if not sa and not sb:
            continue
        va = sa["last"] if sa else None
        vb = sb["last"] if sb else None
        delta = (vb - va) if (va is not None and vb is not None) else None
        lines.append(f"{name:<16}{_fmt(va):>12}{_fmt(vb):>12}{_fmt(delta):>12}")
    ta, tb = a.get("throughput") or {}, b.get("throughput") or {}
    for key in ("steps_per_s", "tokens_per_s"):
        if key in ta or key in tb:
            va, vb = ta.get(key), tb.get(key)
            delta = (vb - va) if (va is not None and vb is not None) else None
            lines.append(f"{key:<16}{_fmt(va):>12}{_fmt(vb):>12}{_fmt(delta):>12}")
    return "\n".join(lines)
