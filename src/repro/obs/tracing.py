"""Phase span tracing: fenced wall-time spans, optional profiler windows,
and device memory stats (docs/observability.md).

JAX dispatch is asynchronous, so a bare ``time.monotonic()`` pair around a
jitted call measures dispatch, not execution.  ``Span`` fences its exit on
``jax.block_until_ready`` over whatever values the caller hands it, which
makes the wall time honest at the cost of a pipeline bubble — so the
trainer opens spans around *windows* (a whole log interval, an eval, a
checkpoint), never around every step.

``ProfileWindow`` arms ``jax.profiler.trace`` for an inclusive step range
(the ``--profile-steps A:B`` flag); the TensorBoard-loadable capture lands
in ``<run_dir>/profile``.  ``jax.named_scope`` annotations inside the
outer step ("dsm_local_phase" / "dsm_global_step") make the two phases
visible inside that capture even though they live in one fused jit.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax


class Span:
    """Context manager measuring a fenced wall-time span.

    ``fence`` values (any pytrees of arrays) are blocked on at exit before
    the clock stops; add them as they become available via ``add_fence``.
    """

    def __init__(self, name: str, *fence: Any):
        self.name = name
        self.seconds = 0.0
        self._fence = list(fence)
        self._t0 = 0.0

    def add_fence(self, *values: Any) -> None:
        self._fence.extend(values)

    def __enter__(self) -> "Span":
        self._t0 = time.monotonic()
        return self

    def __exit__(self, exc_type: Any, *exc: Any) -> None:
        if exc_type is None and self._fence:
            jax.block_until_ready(self._fence)
        self.seconds = time.monotonic() - self._t0
        self._fence = []


class PhaseTotals:
    """Accumulates span seconds / counts per phase name."""

    def __init__(self) -> None:
        self.seconds: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}

    def add(self, name: str, seconds: float, n: int = 1) -> None:
        self.seconds[name] = self.seconds.get(name, 0.0) + float(seconds)
        self.counts[name] = self.counts.get(name, 0) + int(n)

    def ms_per(self, name: str) -> Optional[float]:
        n = self.counts.get(name, 0)
        if n <= 0:
            return None
        return 1e3 * self.seconds[name] / n

    def as_dict(self) -> Dict[str, Dict[str, float]]:
        return {
            name: {
                "seconds": self.seconds[name],
                "count": self.counts[name],
                "ms_per": self.ms_per(name) or 0.0,
            }
            for name in sorted(self.seconds)
        }


def parse_profile_steps(spec: Optional[str]) -> Optional[Tuple[int, int]]:
    """Parse ``"A:B"`` into an inclusive step range; None when unset."""
    if not spec:
        return None
    try:
        a_s, b_s = spec.split(":")
        a, b = int(a_s), int(b_s)
    except ValueError as e:
        raise ValueError(
            f"--profile-steps expects 'A:B' (got {spec!r})"
        ) from e
    if a < 0 or b < a:
        raise ValueError(f"--profile-steps needs 0 <= A <= B (got {spec!r})")
    return a, b


class ProfileWindow:
    """Arms ``jax.profiler.trace`` while the outer step is inside [A, B]."""

    def __init__(self, steps: Optional[Tuple[int, int]], out_dir: str):
        self.steps = steps
        self.out_dir = out_dir
        self.active = False
        self.failed = False

    def tick(self, step: int) -> None:
        """Call once per outer step, before running it."""
        if self.steps is None or self.failed:
            return
        a, b = self.steps
        if not self.active and a <= step <= b:
            try:
                jax.profiler.start_trace(self.out_dir)
                self.active = True
            except Exception:
                self.failed = True  # profiler unavailable on this backend
        elif self.active and step > b:
            self._stop()

    def _stop(self) -> None:
        try:
            jax.profiler.stop_trace()
        except Exception:
            pass
        self.active = False

    def close(self) -> None:
        if self.active:
            self._stop()


def device_memory_stats() -> Optional[Dict[str, Any]]:
    """Live/peak bytes per device, or None when the backend (e.g. CPU)
    doesn't expose memory stats."""
    out = {}
    for d in jax.local_devices():
        try:
            stats = d.memory_stats()
        except Exception:
            stats = None
        if not stats:
            continue
        out[str(d)] = {
            k: int(v)
            for k, v in stats.items()
            if k in ("bytes_in_use", "peak_bytes_in_use", "bytes_limit")
        }
    return out or None


def timeit_fenced(fn: Callable[..., Any], *args: Any, iters: int = 5,
                  warmup: int = 1) -> float:
    """Median fenced seconds per call (used by the perf snapshot)."""
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(iters, 1)):
        t0 = time.monotonic()
        jax.block_until_ready(fn(*args))
        times.append(time.monotonic() - t0)
    times.sort()
    return times[len(times) // 2]
