"""Fault tolerance for DSM training (beyond-paper robustness layer).

The paper targets regimes "where communicating at every step is
prohibitive" — multi-host, preemptible fleets where workers straggle, drop
out, and deliver corrupted contributions.  This package provides:

  * ``faults``  — a deterministic, seeded fault-injection plan
    (:class:`FaultPlan`) producing per-round worker dropouts, stale
    (straggler) contributions, and NaN/inf corruption, consumable by the
    trainer, the launcher (``--faults``), and the chaos tests.
  * ``guards``  — device-side training guards: non-finite-update and
    loss-spike detection with skip-round semantics (the sign momentum ``m``
    is untouched on a skipped round).

The survivor-aware global step itself lives in ``repro.core.dsm``
(:func:`masked_worker_mean`) so the algorithm is robust without importing
this package; see docs/fault_tolerance.md for the full fault model.
"""

from repro.robustness.faults import (
    FaultPlan,
    FaultRound,
    FaultSpec,
    apply_faults,
)
from repro.robustness.guards import (
    GuardState,
    init_guard,
    make_guarded_step,
    tree_all_finite,
    tree_select,
)

__all__ = [
    "FaultPlan",
    "FaultRound",
    "FaultSpec",
    "apply_faults",
    "GuardState",
    "init_guard",
    "make_guarded_step",
    "tree_all_finite",
    "tree_select",
]
