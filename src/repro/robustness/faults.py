"""Deterministic, seeded fault injection for the DSM outer loop.

A :class:`FaultPlan` pre-draws, from one numpy seed, which workers fail in
which outer round and *how*:

  * **drop**     — the worker's contribution never arrives; the survivor-
    aware global step excludes it from the x_tau mean and the worker simply
    re-syncs from x_{t+1,0} at the next round (Algorithm 1's broadcast).
  * **straggle** — the worker misses the communication deadline and delivers
    a stale iterate (its round-start x_{t,0}, i.e. a zero pseudo-gradient
    contribution that dilutes the mean but never poisons it).
  * **corrupt**  — the delivered contribution is NaN-poisoned (flaky HBM /
    wire corruption / a diverged local phase).  The global step must DETECT
    this (per-worker finiteness mask) — corruption is never announced.

The same plan object drives the trainer (``TrainSettings.faults``), the
launcher (``--faults "drop=0.25,straggle=0.1,nan=0.05,seed=0"``), and the
chaos tests, so every faulty run is bit-reproducible — including across a
kill + ``--resume``, because rounds are indexed by the outer step ``t``.

Sign-based aggregation is unusually robust to this fault model (signSGD's
majority-vote heritage, Bernstein et al. 2018): a dropped or stale worker
shifts the pseudo-gradient mean, but only its *sign* reaches x0.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

PyTree = object


class FaultRound(NamedTuple):
    """One outer round's faults, as jit-traceable ``(W,)`` bool arrays."""

    survivors: jnp.ndarray  # True where the contribution arrives at all
    stale: jnp.ndarray      # True where the contribution is the stale x_{t,0}
    corrupt: jnp.ndarray    # True where the contribution is NaN-poisoned


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-round, per-worker fault probabilities + the plan seed."""

    p_drop: float = 0.0
    p_straggle: float = 0.0
    p_corrupt: float = 0.0
    seed: int = 0

    def __post_init__(self):
        for name in ("p_drop", "p_straggle", "p_corrupt"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name}={p} must lie in [0, 1]")

    _KEYS = {"drop": "p_drop", "straggle": "p_straggle", "nan": "p_corrupt",
             "corrupt": "p_corrupt", "seed": "seed"}

    @classmethod
    def parse(cls, spec: str) -> "FaultSpec":
        """Parse the CLI form ``"drop=0.25,straggle=0.1,nan=0.05,seed=3"``."""
        kw = {}
        for item in spec.split(","):
            item = item.strip()
            if not item:
                continue
            if "=" not in item:
                raise ValueError(f"bad fault spec item {item!r} in {spec!r}")
            k, v = item.split("=", 1)
            k = k.strip().lower()
            if k not in cls._KEYS:
                raise ValueError(
                    f"unknown fault key {k!r}; have {sorted(cls._KEYS)}")
            field = cls._KEYS[k]
            kw[field] = int(v) if field == "seed" else float(v)
        return cls(**kw)


class FaultPlan:
    """Pre-drawn ``(steps, W)`` fault masks; ``round(t)`` yields the round's
    :class:`FaultRound`.  Rounds beyond ``steps`` are fault-free (so a run
    extended past the planned horizon degrades gracefully).

    Each round's draws are seeded by ``(spec.seed, t)``, NOT consumed from
    one stream over the whole plan — so the faults of round t are identical
    no matter the plan horizon.  This is what makes kill + resume exact even
    when the resumed run is configured with a different ``steps``."""

    def __init__(self, n_workers: int, steps: int, spec: FaultSpec):
        if n_workers < 1 or steps < 0:
            raise ValueError("need n_workers >= 1 and steps >= 0")
        self.n_workers = n_workers
        self.steps = steps
        self.spec = spec
        self.drop = np.zeros((steps, n_workers), bool)
        self.stale = np.zeros((steps, n_workers), bool)
        self.corrupt = np.zeros((steps, n_workers), bool)
        for t in range(steps):
            rng = np.random.default_rng((spec.seed, t))
            self.drop[t] = rng.random(n_workers) < spec.p_drop
            self.stale[t] = rng.random(n_workers) < spec.p_straggle
            self.corrupt[t] = rng.random(n_workers) < spec.p_corrupt

    @classmethod
    def from_spec(cls, spec: Union[str, FaultSpec], n_workers: int,
                  steps: int) -> "FaultPlan":
        if isinstance(spec, str):
            spec = FaultSpec.parse(spec)
        return cls(n_workers, steps, spec)

    def round(self, t: int) -> FaultRound:
        if 0 <= t < self.steps:
            drop, stale, corrupt = self.drop[t], self.stale[t], self.corrupt[t]
        else:
            drop = stale = corrupt = np.zeros((self.n_workers,), bool)
        return FaultRound(
            survivors=jnp.asarray(~drop),
            stale=jnp.asarray(stale),
            corrupt=jnp.asarray(corrupt),
        )

    def dropped_frac(self) -> float:
        """Fraction of (round, worker) contributions dropped — for comm
        accounting (benchmarks.comm ``survivor_frac = 1 - dropped_frac``)."""
        return float(self.drop.mean()) if self.drop.size else 0.0


def apply_faults(params_w: PyTree, x0: PyTree, faults: FaultRound) -> PyTree:
    """Transform the delivered per-worker iterates per the round's faults.

    Stale workers deliver the round-start ``x0`` (they never finished their
    tau local steps); corrupt workers deliver NaN.  Dropped workers are NOT
    transformed here — exclusion is the *aggregator's* job (the survivor
    mask in the masked worker mean), since a real dropout delivers nothing.
    """

    def leaf(p, g):
        shape = (p.shape[0],) + (1,) * (p.ndim - 1)
        stale = faults.stale.reshape(shape)
        corrupt = faults.corrupt.reshape(shape)
        out = jnp.where(stale, g[None].astype(p.dtype), p)
        return jnp.where(corrupt, jnp.asarray(jnp.nan, p.dtype), out)

    return jax.tree.map(leaf, params_w, x0)
