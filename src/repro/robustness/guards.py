"""Training guards: skip-round protection around any outer step.

``make_guarded_step`` wraps an outer step ``f(state, *args) -> (state',
metrics)`` with device-side acceptance checks:

  * **non-finite update** — any NaN/inf anywhere in the candidate state
    (x0, momentum, per-worker params, base-opt state) rejects the round;
  * **loss spike** — round loss above ``spike_factor`` x a running EMA of
    accepted-round losses rejects the round (momentum hygiene: one poisoned
    pseudo-gradient would otherwise linger in ``m`` for ~1/(1-beta2)
    rounds, the failure mode decoupled-momentum methods like DeMo design
    around).

A rejected round is *skipped*: the previous state — including the sign
momentum ``m`` and the outer counter ``t`` — is kept bit-intact and the
trainer moves on to the next batch (retry-with-fresh-data).  Everything is
computed with ``jnp.where`` selects, so the guarded step stays a single
jittable function with no host sync; the trainer only reads
``guard.bad_streak`` (one scalar) when checkpoint rollback is enabled.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class GuardState(NamedTuple):
    ema: jnp.ndarray         # f32 EMA of accepted-round losses
    seen: jnp.ndarray        # i32 accepted rounds (0 -> EMA uninitialized)
    bad_streak: jnp.ndarray  # i32 consecutive rejected rounds
    skipped: jnp.ndarray     # i32 total rejected rounds


def init_guard() -> GuardState:
    return GuardState(
        ema=jnp.zeros((), jnp.float32),
        seen=jnp.zeros((), jnp.int32),
        bad_streak=jnp.zeros((), jnp.int32),
        skipped=jnp.zeros((), jnp.int32),
    )


def tree_all_finite(tree: PyTree) -> jnp.ndarray:
    """Scalar bool: every element of every floating leaf is finite."""
    ok = jnp.asarray(True)
    for leaf in jax.tree.leaves(tree):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            ok = ok & jnp.isfinite(leaf).all()
    return ok


def tree_select(pred: jnp.ndarray, on_true: PyTree, on_false: PyTree) -> PyTree:
    """Leafwise ``where(pred, on_true, on_false)`` (scalar pred)."""
    return jax.tree.map(lambda a, b: jnp.where(pred, a, b), on_true, on_false)


def make_guarded_step(
    step_fn: Callable[..., tuple[PyTree, dict]],
    *,
    nonfinite: bool = True,
    spike_factor: float = 0.0,
    ema_beta: float = 0.9,
) -> Callable[..., tuple[PyTree, GuardState, dict]]:
    """Wrap ``step_fn(state, *args)`` into
    ``guarded(state, guard, *args) -> (state', guard', metrics)``.

    ``spike_factor <= 0`` disables spike detection; ``nonfinite=False``
    disables the full-state finiteness check (a non-finite loss always
    rejects).  The first accepted round seeds the EMA with its loss.
    """
    if spike_factor < 0:
        raise ValueError("spike_factor must be >= 0 (0 disables)")

    def guarded(state, guard: GuardState, *args):
        new_state, metrics = step_fn(state, *args)
        loss = jnp.asarray(metrics["loss"], jnp.float32)
        ok = jnp.isfinite(loss)
        if nonfinite:
            ok = ok & tree_all_finite(new_state)
        if spike_factor > 0:
            spike = (guard.seen > 0) & (loss > spike_factor * guard.ema)
            ok = ok & ~spike

        ema_next = jnp.where(
            guard.seen == 0, loss,
            ema_beta * guard.ema + (1.0 - ema_beta) * loss,
        )
        new_guard = GuardState(
            ema=jnp.where(ok, ema_next, guard.ema),
            seen=guard.seen + ok.astype(jnp.int32),
            bad_streak=jnp.where(ok, 0, guard.bad_streak + 1),
            skipped=guard.skipped + (~ok).astype(jnp.int32),
        )
        out_state = tree_select(ok, new_state, state)
        metrics = dict(metrics, guard_ok=ok, bad_streak=new_guard.bad_streak,
                       skipped_rounds=new_guard.skipped)
        if "pack" in metrics:
            # record the verdict in the on-device metric pack (repro.obs)
            from repro.obs.metrics import set_guard_flag

            metrics["pack"] = set_guard_flag(metrics["pack"], ok)
        return out_state, new_guard, metrics

    return guarded
