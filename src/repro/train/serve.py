"""Batched serving: prefill a prompt batch, then autoregressive decode.

CPU-scale engine used by examples/serve_model.py and the integration tests;
the production decode path is the same ``decode_step`` the dry-run lowers
for decode_32k / long_500k.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T


def generate(
    params,
    cfg,
    prompt_tokens: jnp.ndarray,      # (B, S_prompt) int32
    max_new_tokens: int = 32,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    extra_batch: Optional[dict] = None,   # frames/patches for encdec/vlm
):
    """Greedy (or temperature) decoding. Returns (tokens (B, new), stats)."""
    B, S = prompt_tokens.shape
    max_len = S + max_new_tokens
    batch = {"tokens": prompt_tokens, **(extra_batch or {})}

    t0 = time.time()
    # prefill produces a cache sized to the prompt; re-home it into a
    # max_len cache so decode can append.
    logits, pcache = jax.jit(lambda p, b: T.prefill(p, b, cfg, remat=False))(params, batch)
    cache = T.init_cache(cfg, B, max_len, cfg.act_dtype)
    cache = _splice_cache(cache, pcache, cfg, S)
    prefill_s = time.time() - t0

    dec = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))

    def pick(logits, key):
        if temperature <= 0.0:
            return jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, : cfg.vocab_size] / temperature, axis=-1
        ).astype(jnp.int32)

    rng = rng if rng is not None else jax.random.PRNGKey(0)
    out = []
    n_prefix = cfg.n_patches if cfg.family == "vlm" else 0
    tok = pick(logits, rng)
    out.append(tok)
    t0 = time.time()
    for i in range(max_new_tokens - 1):
        rng, sub = jax.random.split(rng)
        pos = jnp.int32(S + n_prefix + i)
        logits, cache = dec(params, cache, tok, pos)
        tok = pick(logits, sub)
        out.append(tok)
    decode_s = time.time() - t0
    return jnp.stack(out, axis=1), {
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "tok_per_s": (max_new_tokens - 1) * B / max(decode_s, 1e-9),
    }


def _splice_cache(big, small, cfg, prompt_len: int):
    """Copy a prefill cache (length = prompt) into a longer decode cache.

    Structure-aware: sliding-window k/v are ring buffers (position p lives
    at slot p % window; prefill emits the last ``w_small`` positions in
    natural order), full-attention k/v pad at the end, recurrent states
    copy through.
    """

    def splice_leaf(kind: str, name: str, big_leaf, small_leaf):
        mixer = kind.split(":")[0]
        if mixer in ("ssm", "rglru") or name in ("kx", "vx") or (
            big_leaf.shape == small_leaf.shape and mixer not in ("swa",)
        ):
            return small_leaf.astype(big_leaf.dtype)
        ax = big_leaf.ndim - 3  # seq axis of (..., S, kvh, hd)
        w_big, w_small = big_leaf.shape[ax], small_leaf.shape[ax]
        pad = [(0, 0)] * big_leaf.ndim
        pad[ax] = (0, w_big - w_small)
        out = jnp.pad(small_leaf.astype(big_leaf.dtype), pad)
        if mixer == "swa":
            out = jnp.roll(out, (prompt_len - w_small) % w_big, axis=ax)
        return out

    def splice_entry(kind, big_e, small_e):
        return {
            name: splice_leaf(kind, name, big_e[name], small_e[name])
            for name in big_e
        }

    out = {"blocks": {}, "rem": []}
    for j, kind in enumerate(cfg.pattern):
        keyn = f"p{j}"
        if keyn in big["blocks"]:
            out["blocks"][keyn] = splice_entry(
                kind, big["blocks"][keyn], small["blocks"][keyn])
    for i in range(len(big["rem"])):
        out["rem"].append(
            splice_entry(cfg.pattern[i], big["rem"][i], small["rem"][i]))
    out["rem"] = tuple(out["rem"])
    return out
