"""Training harness: runs any algorithm (DSM or baseline) on any ModelConfig.

This is the engine behind the paper-reproduction experiments (benchmarks/)
and the runnable examples.  CPU-scale by design: reduced configs, simulated
workers (leading W axis).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DSMConfig,
    cosine_with_warmup,
    constant,
    dsm_init,
    get_base_optimizer,
    make_dsm_step,
)
from repro.core import baselines as BL
from repro.data.pipeline import MarkovCorpus, dsm_batches, eval_batch
from repro.models import transformer as T

ALGORITHMS = (
    "dsm", "slowmo", "signed_slowmo", "lookahead", "signed_lookahead",
    "global_adamw", "local_avg", "perstep", "mv_signsgd",
)


@dataclasses.dataclass
class TrainSettings:
    algorithm: str = "dsm"
    base_opt: str = "adamw"
    n_workers: int = 8
    tau: int = 12
    steps: int = 60                 # outer steps
    b_micro: int = 4
    seq: int = 128
    peak_lr: float = 1e-3
    warmup: int = 24
    schedule: str = "cosine"
    global_lr: float = 1.0          # eta (DSM) / alpha (SlowMo)
    slow_beta: float = 0.5          # SlowMo / lookahead momentum
    dsm_beta1: float = 0.95
    dsm_beta2: float = 0.98
    dsm_wd: float = 0.1
    sign_mode: str = "sign"
    seed: int = 0
    eval_every: int = 10
    eval_batch: int = 16
    heterogeneous: bool = True
    use_kernel: bool = False
    zero_sharded: bool = False      # ZeRO-sharded global step over local devices
    device_parallel_local: bool = False  # shard_map local phase over "worker"


def _schedule(s: TrainSettings):
    if s.schedule == "cosine":
        return cosine_with_warmup(s.peak_lr, s.steps, warmup_steps=s.warmup)
    return constant(s.peak_lr)


def build_algorithm(loss_fn, s: TrainSettings, mesh=None):
    """Returns (init(params, n_workers) -> state, step(state, batch[, rng]),
    eval_params(state) -> params, comm_multiplier).

    ``mesh``: optional ("worker", "zero", "model") mesh; with
    ``s.zero_sharded`` the DSM global step runs ZeRO-sharded on it, and with
    ``s.device_parallel_local`` the local phase of DSM / the local-step
    baselines runs shard_mapped over its worker axis.
    """
    base = get_base_optimizer(s.base_opt)
    sched = _schedule(s)
    local_kw = dict(device_parallel=s.device_parallel_local, mesh=mesh)

    if s.algorithm in ("dsm", "signed_lookahead"):
        cfg = DSMConfig(
            tau=s.tau, global_lr=s.global_lr, beta1=s.dsm_beta1,
            beta2=s.dsm_beta2, weight_decay=s.dsm_wd, sign_mode=s.sign_mode,
            sign_bound=float(s.tau), use_kernel=s.use_kernel,
            zero_sharded=s.zero_sharded,
            device_parallel_local=s.device_parallel_local,
        )
        if s.algorithm == "signed_lookahead":
            cfg = dataclasses.replace(cfg, beta1=s.slow_beta, beta2=s.slow_beta,
                                      weight_decay=0.0)
        step = make_dsm_step(loss_fn, base, cfg, sched, mesh=mesh)
        needs_rng = s.sign_mode != "sign"

        def init(params, n_workers):
            return dsm_init(params, base, n_workers, mesh=mesh,
                            global_sharded=s.zero_sharded)

        def stepper(state, batch, rng):
            return step(state, batch, rng) if needs_rng else step(state, batch)

        return init, stepper, lambda st: st.x0, 1.0

    if s.algorithm in ("slowmo", "signed_slowmo", "lookahead", "global_adamw",
                       "local_avg"):
        maker = {
            "slowmo": lambda: BL.slowmo(loss_fn, base, s.tau, sched,
                                        beta=s.slow_beta, alpha=s.global_lr,
                                        **local_kw),
            "signed_slowmo": lambda: BL.signed_slowmo(loss_fn, base, s.tau, sched,
                                                      beta=s.slow_beta, eta=s.global_lr,
                                                      **local_kw),
            "lookahead": lambda: BL.lookahead(loss_fn, base, s.tau, sched,
                                              beta=s.slow_beta, eta=s.global_lr,
                                              **local_kw),
            "global_adamw": lambda: BL.global_adamw(loss_fn, base, s.tau, sched,
                                                    eta=s.global_lr, **local_kw),
            "local_avg": lambda: BL.local_avg(loss_fn, base, s.tau, sched,
                                              **local_kw),
        }[s.algorithm]
        init, step = maker()
        return init, (lambda st, b, rng: step(st, b)), (lambda st: st.x0), 1.0

    if s.algorithm == "perstep":
        init, step = BL.make_perstep_dp_step(loss_fn, base, s.tau, sched)
        return init, (lambda st, b, rng: step(st, b)), (lambda st: st.params), float(s.tau)

    if s.algorithm == "mv_signsgd":
        init, step = BL.make_mv_signsgd_step(
            loss_fn, s.tau, gamma=s.peak_lr, eta=s.global_lr * s.peak_lr,
            beta=s.slow_beta, bound=1.0,
        )
        return init, (lambda st, b, rng: step(st, b, rng)), (lambda st: st.x), 1.0

    raise ValueError(f"unknown algorithm {s.algorithm!r}")


def run_training(cfg, s: TrainSettings, corpus=None, log: Optional[Callable] = None):
    """Train; returns dict(history, eval_losses, final_eval, tokens, comm_rounds)."""
    corpus = corpus or MarkovCorpus(cfg.vocab_size, seed=1)
    key = jax.random.PRNGKey(s.seed)
    params = T.init_params(key, cfg)

    def loss_fn(p, mb):
        return T.loss_fn(p, mb, cfg, remat=False)

    # ONE mesh construction for every mesh-consuming feature: zero_sharded,
    # device_parallel_local, and whatever comes next all share this path
    # (host_training_mesh raises a clear error when n_workers does not
    # divide the device grid).
    mesh = None
    if s.zero_sharded or s.device_parallel_local:
        from repro.launch.mesh import host_training_mesh

        mesh = host_training_mesh(s.n_workers)

    init, step, eval_params, comm_mult = build_algorithm(loss_fn, s, mesh=mesh)
    state = init(params, s.n_workers)
    jstep = jax.jit(step)
    eval_loss_fn = jax.jit(lambda p, b: T.loss_fn(p, b, cfg, remat=False))

    batches = dsm_batches(
        corpus, s.n_workers, s.tau, 1, s.b_micro, s.seq,
        seed=s.seed, heterogeneous=s.heterogeneous,
    )
    ev_batch = eval_batch(corpus, s.eval_batch, s.seq)
    needs_accum = s.algorithm in ("dsm", "signed_lookahead")

    history, evals = [], []
    t0 = time.time()
    for t in range(s.steps):
        key, sub = jax.random.split(key)
        batch = next(batches)
        if not needs_accum:
            batch = {k: v[:, :, 0] for k, v in batch.items()}
        batch = jax.tree.map(jnp.asarray, batch)
        state, metrics = jstep(state, batch, sub)
        history.append(float(metrics["loss"]))
        if (t + 1) % s.eval_every == 0 or t == s.steps - 1:
            el = float(eval_loss_fn(eval_params(state), ev_batch))
            evals.append((t + 1, el))
            if log:
                log(f"step {t+1:4d} train={history[-1]:.4f} eval={el:.4f}")

    return {
        "history": history,
        "eval_losses": evals,
        "final_eval": evals[-1][1] if evals else float("nan"),
        "tokens": s.steps * s.tau * s.n_workers * s.b_micro * s.seq,
        "comm_rounds": int(s.steps * comm_mult),
        "wall_s": time.time() - t0,
        "state": state,
    }
