"""Training harness: runs any algorithm (DSM or baseline) on any ModelConfig.

This is the engine behind the paper-reproduction experiments (benchmarks/)
and the runnable examples.  CPU-scale by design: reduced configs, simulated
workers (leading W axis).
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import IDX as METRIC_IDX, METRIC_NAMES

from repro.core import (
    DSMConfig,
    cosine_with_warmup,
    constant,
    dsm_init,
    get_base_optimizer,
    make_dsm_step,
)
from repro.core import baselines as BL
from repro.data.pipeline import MarkovCorpus, dsm_batches, eval_batch
from repro.models import transformer as T

ALGORITHMS = (
    "dsm", "slowmo", "signed_slowmo", "lookahead", "signed_lookahead",
    "global_adamw", "local_avg", "perstep", "mv_signsgd",
)


@dataclasses.dataclass
class TrainSettings:
    algorithm: str = "dsm"
    base_opt: str = "adamw"
    n_workers: int = 8
    tau: int = 12
    steps: int = 60                 # outer steps
    b_micro: int = 4
    seq: int = 128
    peak_lr: float = 1e-3
    warmup: int = 24
    schedule: str = "cosine"
    global_lr: float = 1.0          # eta (DSM) / alpha (SlowMo)
    slow_beta: float = 0.5          # SlowMo / lookahead momentum
    dsm_beta1: float = 0.95
    dsm_beta2: float = 0.98
    dsm_wd: float = 0.1
    sign_mode: str = "sign"
    seed: int = 0
    eval_every: int = 10
    eval_batch: int = 16
    heterogeneous: bool = True
    use_kernel: bool = False
    zero_sharded: bool = False      # ZeRO-sharded global step over local devices
    device_parallel_local: bool = False  # shard_map local phase over "worker"
    # --- robustness (docs/fault_tolerance.md) ---
    faults: Any = None              # FaultPlan | FaultSpec | spec str, e.g.
    #                                 "drop=0.25,straggle=0.1,nan=0.05,seed=0"
    mask_nonfinite: bool = False    # survivor-aware mean w/o injection (DSM)
    guard_nonfinite: bool = False   # reject rounds with NaN/inf in the state
    guard_spike_factor: float = 0.0  # reject rounds w/ loss > factor*EMA (0=off)
    guard_ema_beta: float = 0.9     # loss EMA for spike detection
    guard_patience: int = 5         # K consecutive bad rounds -> rollback
    guard_max_rollbacks: int = 2    # bounded retry; exceeded -> RuntimeError
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0       # outer steps; <=0 -> max(1, steps // 5)
    checkpoint_keep: int = 3        # rotated retention
    resume: bool = False            # auto-resume from checkpoint_dir's latest
    # --- runtime sanitizers (docs/analysis.md) ---
    sanitize: bool = False          # transfer guard around the hot loop +
    #                                 recompilation counter (steady-state outer
    #                                 step must compile exactly once)
    sanitize_nans: bool = False     # jax_debug_nans over the whole loop (the
    #                                 chaos tier: masked NaNs must never reach
    #                                 a jit output)
    # --- observability (docs/observability.md) ---
    run_dir: Optional[str] = None   # obs run directory: manifest.json /
    #                                 events.jsonl / scalars.csv / profile/
    log_every: int = 0              # metric flush + log cadence in outer
    #                                 steps; <=0 -> eval_every
    profile_steps: Optional[str] = None  # "A:B": jax.profiler.trace window
    #                                 (inclusive outer-step range)


def _schedule(s: TrainSettings):
    if s.schedule == "cosine":
        return cosine_with_warmup(s.peak_lr, s.steps, warmup_steps=s.warmup)
    return constant(s.peak_lr)


def build_algorithm(loss_fn, s: TrainSettings, mesh=None):
    """Returns (init(params, n_workers) -> state, step(state, batch[, rng]),
    eval_params(state) -> params, comm_multiplier).

    ``mesh``: optional ("worker", "zero", "model") mesh; with
    ``s.zero_sharded`` the DSM global step runs ZeRO-sharded on it, and with
    ``s.device_parallel_local`` the local phase of DSM / the local-step
    baselines runs shard_mapped over its worker axis.
    """
    base = get_base_optimizer(s.base_opt)
    sched = _schedule(s)
    local_kw = dict(device_parallel=s.device_parallel_local, mesh=mesh)

    if s.algorithm in ("dsm", "signed_lookahead"):
        cfg = DSMConfig(
            tau=s.tau, global_lr=s.global_lr, beta1=s.dsm_beta1,
            beta2=s.dsm_beta2, weight_decay=s.dsm_wd, sign_mode=s.sign_mode,
            sign_bound=float(s.tau), use_kernel=s.use_kernel,
            zero_sharded=s.zero_sharded,
            device_parallel_local=s.device_parallel_local,
            mask_nonfinite=s.mask_nonfinite,
        )
        if s.algorithm == "signed_lookahead":
            cfg = dataclasses.replace(cfg, beta1=s.slow_beta, beta2=s.slow_beta,
                                      weight_decay=0.0)
        step = make_dsm_step(loss_fn, base, cfg, sched, mesh=mesh)
        needs_rng = s.sign_mode != "sign"

        def init(params, n_workers):
            return dsm_init(params, base, n_workers, mesh=mesh,
                            global_sharded=s.zero_sharded)

        def stepper(state, batch, rng, faults=None):
            return step(state, batch, rng if needs_rng else None, faults)

        return init, stepper, lambda st: st.x0, 1.0

    if s.algorithm in ("slowmo", "signed_slowmo", "lookahead", "global_adamw",
                       "local_avg"):
        maker = {
            "slowmo": lambda: BL.slowmo(loss_fn, base, s.tau, sched,
                                        beta=s.slow_beta, alpha=s.global_lr,
                                        **local_kw),
            "signed_slowmo": lambda: BL.signed_slowmo(loss_fn, base, s.tau, sched,
                                                      beta=s.slow_beta, eta=s.global_lr,
                                                      **local_kw),
            "lookahead": lambda: BL.lookahead(loss_fn, base, s.tau, sched,
                                              beta=s.slow_beta, eta=s.global_lr,
                                              **local_kw),
            "global_adamw": lambda: BL.global_adamw(loss_fn, base, s.tau, sched,
                                                    eta=s.global_lr, **local_kw),
            "local_avg": lambda: BL.local_avg(loss_fn, base, s.tau, sched,
                                              **local_kw),
        }[s.algorithm]
        init, step = maker()
        return init, (lambda st, b, rng, faults=None: step(st, b)), (lambda st: st.x0), 1.0

    if s.algorithm == "perstep":
        init, step = BL.make_perstep_dp_step(loss_fn, base, s.tau, sched)
        return (init, (lambda st, b, rng, faults=None: step(st, b)),
                (lambda st: st.params), float(s.tau))

    if s.algorithm == "mv_signsgd":
        init, step = BL.make_mv_signsgd_step(
            loss_fn, s.tau, gamma=s.peak_lr, eta=s.global_lr * s.peak_lr,
            beta=s.slow_beta, bound=1.0,
        )
        return init, (lambda st, b, rng, faults=None: step(st, b, rng)), (lambda st: st.x), 1.0

    raise ValueError(f"unknown algorithm {s.algorithm!r}")


_DSM_FAMILY = ("dsm", "signed_lookahead")


def _decode_metrics_row(fetched: dict) -> np.ndarray:
    """Host-side: one scalars.csv row from a fetched per-round metrics dict.

    DSM-family steps carry the full on-device pack; baseline algorithms get
    the loss / gamma (+ guard verdict) slots with NaN elsewhere.
    """
    if "pack" in fetched:
        return np.asarray(fetched["pack"], np.float64).reshape(-1)
    row = np.full((len(METRIC_NAMES),), np.nan)
    for name in ("loss", "last_loss", "gamma", "guard_ok"):
        if name in fetched:
            row[METRIC_IDX[name]] = float(np.asarray(fetched[name]))
    return row


def _resolve_fault_plan(s: TrainSettings):
    if not s.faults:
        return None
    from repro.robustness.faults import FaultPlan

    if s.algorithm not in _DSM_FAMILY:
        raise ValueError(
            "fault injection needs the survivor-aware DSM step family; "
            f"got algorithm={s.algorithm!r}")
    if isinstance(s.faults, FaultPlan):
        return s.faults
    return FaultPlan.from_spec(s.faults, s.n_workers, s.steps)


def run_training(cfg, s: TrainSettings, corpus=None, log: Optional[Callable] = None):
    """Train; returns dict(history, eval_losses, final_eval, tokens, comm_rounds).

    Robustness settings (docs/fault_tolerance.md):

      * ``faults``          — deterministic seeded fault injection (DSM only).
      * ``guard_nonfinite`` / ``guard_spike_factor`` — skip-round guards; with
        ``checkpoint_dir`` set, ``guard_patience`` consecutive bad rounds roll
        the run back to the last checkpoint, at most ``guard_max_rollbacks``
        times before raising RuntimeError.
      * ``checkpoint_dir`` / ``checkpoint_every`` / ``resume`` — atomic rotated
        checkpoints of the FULL training state (optimizer state, PRNG key,
        guard state, metric history, data position via the step index), so a
        killed run restarts bit-exactly from the last complete checkpoint.

    Per-round metrics stay on device (async) and are only fetched at
    eval/log/checkpoint points; ``history`` contents are unchanged.
    """
    corpus = corpus or MarkovCorpus(cfg.vocab_size, seed=1)
    key = jax.random.PRNGKey(s.seed)
    params = T.init_params(key, cfg)

    def loss_fn(p, mb):
        return T.loss_fn(p, mb, cfg, remat=False)

    # ONE mesh construction for every mesh-consuming feature: zero_sharded,
    # device_parallel_local, and whatever comes next all share this path
    # (host_training_mesh raises a clear error when n_workers does not
    # divide the device grid).
    mesh = None
    if s.zero_sharded or s.device_parallel_local:
        from repro.launch.mesh import host_training_mesh

        mesh = host_training_mesh(s.n_workers)

    init, step, eval_params, comm_mult = build_algorithm(loss_fn, s, mesh=mesh)
    state = init(params, s.n_workers)

    plan = _resolve_fault_plan(s)
    guards_on = s.guard_nonfinite or s.guard_spike_factor > 0
    if guards_on:
        from repro.robustness import guards as G

        guard = G.init_guard()
        step_fn = G.make_guarded_step(
            step, nonfinite=s.guard_nonfinite,
            spike_factor=s.guard_spike_factor, ema_beta=s.guard_ema_beta)
    else:
        guard = None
        step_fn = step
    # distinct compile-log names so the sanitizer's recompilation counter can
    # tell the outer step from the (also jitted) eval loss
    step_fn.__name__ = "train_step"
    jstep = jax.jit(step_fn)

    def eval_loss(p, b):
        return T.loss_fn(p, b, cfg, remat=False)

    eval_loss_fn = jax.jit(eval_loss)

    ckpt_on = bool(s.checkpoint_dir)
    ckpt_every = s.checkpoint_every if s.checkpoint_every > 0 else max(1, s.steps // 5)
    rollback_on = ckpt_on and guards_on and s.guard_patience > 0
    if ckpt_on:
        from repro.checkpoint import checkpoint as CK

    def ckpt_tree(state, guard, key):
        tree = {"state": state, "key": key}
        if guard is not None:
            tree["guard"] = guard
        return tree

    def reshard(state):
        # npz restore lands on the default device; put DSM state back into
        # its mesh layout so the compiled step consumes it shard-in-place
        if mesh is not None and s.algorithm in _DSM_FAMILY:
            from repro.distributed import zero as Z

            return Z.shard_dsm_state(state, mesh, global_sharded=s.zero_sharded)
        return state

    def make_batches(skip: int = 0):
        # data-pipeline position == outer-step index: the stream is a pure
        # function of (corpus, seed), so resume replays `skip` rounds
        it = dsm_batches(
            corpus, s.n_workers, s.tau, 1, s.b_micro, s.seq,
            seed=s.seed, heterogeneous=s.heterogeneous,
        )
        for _ in range(skip):
            next(it)
        return it

    history, evals = [], []
    start_step, rollbacks = 0, 0
    if s.resume and ckpt_on:
        restored = CK.restore_latest(s.checkpoint_dir, ckpt_tree(state, guard, key))
        if restored is not None:
            tree, start_step, extra = restored
            state, key = reshard(tree["state"]), tree["key"]
            if guards_on:
                guard = tree["guard"]
            history = [float(x) for x in extra.get("history", [])]  # resume = a sync point
            evals = [tuple(e) for e in extra.get("evals", [])]
            # cumulative guard counters survive the restart (the guard state
            # itself is restored bit-exact; the rollback count lives here)
            rollbacks = int(extra.get("rollbacks", 0))
            if log:
                log(f"resumed from checkpoint at step {start_step}")
    if ckpt_on and start_step == 0:
        # step-0 checkpoint: the rollback target always exists
        CK.save_checkpoint(s.checkpoint_dir, ckpt_tree(state, guard, key), 0,
                           keep=s.checkpoint_keep,
                           extra={"history": [], "evals": [],
                                  "rollbacks": 0, "skipped_rounds": 0})

    ev_batch = eval_batch(corpus, s.eval_batch, s.seq)
    needs_accum = s.algorithm in _DSM_FAMILY

    def prep_batch(raw):
        if not needs_accum:
            raw = {k: v[:, :, 0] for k, v in raw.items()}
        return jax.tree.map(jnp.asarray, raw)

    # --- observability (docs/observability.md): run sinks + comm ledger +
    # phase spans + profiler window.  Per-round metrics stay on device in
    # `pending`; ALL host reads happen in flush_metrics() at the sanctioned
    # sync points (log/eval/checkpoint/rollback), outside the transfer
    # guard.  The comm-ledger lowering is itself a compile, so it runs
    # BEFORE the sanitizers arm their recompilation counter. ---
    obs_on = bool(s.run_dir)
    writer = None
    profile = None
    phase_totals = None
    probe_batch = probe_key = probe_fr = None
    log_every = s.log_every if s.log_every > 0 else s.eval_every
    pending: list = []  # (outer step number, on-device metrics dict)
    if obs_on:
        from repro.obs import sinks as OS
        from repro.obs import tracing as OT
        from repro.obs.ledger import compile_time_ledger

        manifest = OS.build_manifest(
            run_name=os.path.basename(os.path.normpath(s.run_dir)),
            settings=s, model_cfg=cfg, mesh=mesh)
        writer = OS.RunWriter(s.run_dir, manifest, resume=start_step > 0)
        phase_totals = OT.PhaseTotals()
        profile = OT.ProfileWindow(OT.parse_profile_steps(s.profile_steps),
                                   os.path.join(s.run_dir, "profile"))
        if start_step > 0:
            writer.event("resumed", step=start_step)
        probe_batch = prep_batch(next(make_batches(start_step)))
        probe_key = jax.random.PRNGKey(s.seed)
        probe_fr = plan.round(start_step) if plan is not None else None
        probe_args = ((state, guard, probe_batch, probe_key, probe_fr)
                      if guards_on
                      else (state, probe_batch, probe_key, probe_fr))
        ledger = compile_time_ledger(
            step_fn, probe_args,
            params=eval_params(state),
            algo="dsm" if s.algorithm in _DSM_FAMILY else s.algorithm,
            tau=s.tau,
            phase="global_zero" if s.zero_sharded else "global_dense",
            mesh=mesh, name="train_step")
        writer.event("comm_ledger", **ledger)

    def flush_metrics():
        """ONE device_get for every pending round; returns the last decoded
        scalar row (dict) or None.  Closes the running train-window span —
        the fetch is the fence."""
        nonlocal window_t0, window_steps
        if not pending:
            return None
        fetched = jax.device_get([m for _, m in pending])
        if obs_on and window_steps:
            dt = time.monotonic() - window_t0
            phase_totals.add("train_window", dt, n=window_steps)
            writer.span("train_window", dt, n=window_steps,
                        step=pending[-1][0])
        row = None
        for (step_no, _), m in zip(pending, fetched):
            vals = _decode_metrics_row(m)
            if writer is not None:
                writer.metrics_row(step_no, vals)
            row = dict(zip(METRIC_NAMES, (float(v) for v in vals)))
        pending.clear()
        window_steps = 0
        window_t0 = time.monotonic()
        return row

    # --- runtime sanitizers (docs/analysis.md): recompilation counter over
    # the whole loop, debug_nans for the chaos tier, transfer guard around
    # each step call (the eval/log/checkpoint host reads below stay OUTSIDE
    # the guard — those are the sanctioned sync points) ---
    recompiles = None
    step_guard = contextlib.nullcontext
    loop_ctx = contextlib.ExitStack()
    if s.sanitize or s.sanitize_nans:
        from repro.analysis import sanitize as SAN

        if s.sanitize:
            recompiles = loop_ctx.enter_context(SAN.RecompilationCounter())
            step_guard = SAN.no_implicit_host_sync
        if s.sanitize_nans:
            loop_ctx.enter_context(SAN.debug_nans())

    def ckpt_extra():
        return {"history": history, "evals": [list(e) for e in evals],
                "rollbacks": rollbacks,
                "skipped_rounds": int(guard.skipped) if guards_on else 0}

    batches = make_batches(start_step)
    t = start_step
    t0 = time.time()
    window_t0 = time.monotonic()
    window_steps = 0
    last_row = None
    try:
        while t < s.steps:
            if profile is not None:
                profile.tick(t)
            key, sub = jax.random.split(key)
            batch = prep_batch(next(batches))
            fr = plan.round(t) if plan is not None else None
            with step_guard():
                if guards_on:
                    state, guard, metrics = jstep(state, guard, batch, sub, fr)
                else:
                    state, metrics = jstep(state, batch, sub, fr)
                # device scalars: fetched only at eval/log/checkpoint points
                # (the old float() here blocked on the device every outer step)
                history.append(metrics["loss"])
                pending.append((t + 1, metrics))
                window_steps += 1

            if rollback_on and int(guard.bad_streak) >= s.guard_patience:
                # the ONE per-round host read rollback requires (a scalar i32)
                row = flush_metrics()  # rejected rounds are still observations
                last_row = row or last_row
                if rollbacks >= s.guard_max_rollbacks:
                    raise RuntimeError(
                        f"training diverged: {int(guard.bad_streak)} consecutive "
                        f"bad rounds at step {t} after {rollbacks} rollbacks")
                rollbacks += 1
                tree, t_ck, extra = CK.restore_latest(
                    s.checkpoint_dir, ckpt_tree(state, guard, key))
                state, key = reshard(tree["state"]), tree["key"]
                guard = tree["guard"]._replace(bad_streak=jnp.zeros((), jnp.int32))
                history = [float(x) for x in extra.get("history", [])]  # rollback = a sync point
                evals = [tuple(e) for e in extra.get("evals", [])]
                if writer is not None:
                    writer.event("rollback", step=t, to_step=t_ck, n=rollbacks)
                if log:
                    log(f"rollback #{rollbacks}: step {t} -> checkpoint at {t_ck}")
                batches = make_batches(t_ck)
                t = t_ck
                window_t0 = time.monotonic()
                continue

            t += 1
            is_eval = t % s.eval_every == 0 or t == s.steps
            is_log = t % log_every == 0
            did_ckpt = ckpt_on and t % ckpt_every == 0
            if is_eval or is_log or did_ckpt:
                # metric flush: ONE async fetch covering every round since
                # the last sync point, with a step-consistent row to log
                row = flush_metrics()
                last_row = row or last_row
            if is_eval:
                if obs_on:
                    with OT.Span("eval") as sp:  # float() is the fence
                        el = float(eval_loss_fn(eval_params(state), ev_batch))
                    phase_totals.add("eval", sp.seconds)
                    writer.span("eval", sp.seconds, step=t)
                    writer.event("eval", step=t, eval_loss=el)
                else:
                    el = float(eval_loss_fn(eval_params(state), ev_batch))
                evals.append((t, el))
                if log:
                    train = last_row["loss"] if last_row else float(history[-1])
                    log(f"step {t:4d} train={train:.4f} eval={el:.4f}")
            elif is_log and log and last_row is not None:
                log(f"step {t:4d} train={last_row['loss']:.4f}")
            if did_ckpt:
                history = [float(x) for x in history]  # checkpoint = a sync point
                if obs_on:
                    with OT.Span("checkpoint", state) as sp:
                        CK.save_checkpoint(
                            s.checkpoint_dir, ckpt_tree(state, guard, key), t,
                            keep=s.checkpoint_keep, extra=ckpt_extra())
                    phase_totals.add("checkpoint", sp.seconds)
                    writer.span("checkpoint", sp.seconds, step=t)
                    writer.event("checkpoint", step=t)
                else:
                    CK.save_checkpoint(
                        s.checkpoint_dir, ckpt_tree(state, guard, key), t,
                        keep=s.checkpoint_keep, extra=ckpt_extra())
            if obs_on and (is_eval or is_log or did_ckpt):
                # eval/checkpoint time must not leak into the next train window
                window_t0 = time.monotonic()
    finally:
        loop_ctx.close()
        if profile is not None:
            profile.close()

    if recompiles is not None:
        # steady state: the outer step compiles EXACTLY once; a second
        # compile means a shape/dtype-polymorphic step (SanitizeError)
        recompiles.assert_steady_state("train_step", max_compiles=1)

    wall = time.time() - t0
    tokens = s.steps * s.tau * s.n_workers * s.b_micro * s.seq
    last_row = flush_metrics() or last_row  # tail rounds (early exits)
    phase_ms = None
    if obs_on:
        steps_done = t - start_step
        # post-run phase probe: local phase and full outer step cannot be
        # separately fenced in-loop (one fused jit), so re-time both fenced
        # here; global step = outer step - local phase.  The probe fns get
        # their own jits/names so the recompilation counter (already closed)
        # and its steady-state assertion never see them.
        if s.algorithm in _DSM_FAMILY and steps_done > 0:
            from repro.core import make_local_phase

            lp = make_local_phase(
                loss_fn, get_base_optimizer(s.base_opt), accum=True,
                device_parallel=s.device_parallel_local, mesh=mesh)

            def local_phase_probe(p, bs, b):
                return lp(p, bs, b, jnp.float32(s.peak_lr), jnp.int32(0))

            local_s = OT.timeit_fenced(
                jax.jit(local_phase_probe),
                state.params, state.base_state, probe_batch, iters=3)
            step_args = ((state, guard, probe_batch, probe_key, probe_fr)
                         if guards_on
                         else (state, probe_batch, probe_key, probe_fr))
            step_s = OT.timeit_fenced(jstep, *step_args, iters=3)
            phase_totals.add("local_phase", local_s)
            phase_totals.add("global_step", max(step_s - local_s, 0.0))
            writer.span("local_phase", local_s, probe=True)
            writer.span("global_step", max(step_s - local_s, 0.0), probe=True)
        mem = OT.device_memory_stats()
        if mem is not None:
            writer.event("device_memory", stats=mem)
        writer.event(
            "finished", steps=steps_done, wall_s=wall,
            steps_per_s=steps_done / wall if wall > 0 else None,
            tokens=tokens,
            tokens_per_s=tokens / wall if wall > 0 else None,
            skipped_rounds=int(guard.skipped) if guards_on else 0,
            rollbacks=rollbacks)
        phase_ms = phase_totals.as_dict()
        writer.close()

    history = [float(x) for x in history]
    return {
        "history": history,
        "eval_losses": evals,
        "final_eval": evals[-1][1] if evals else float("nan"),
        "tokens": tokens,
        "comm_rounds": int(s.steps * comm_mult),
        "wall_s": wall,
        "skipped_rounds": int(guard.skipped) if guards_on else 0,
        "rollbacks": rollbacks,
        "step_compiles": recompiles.count("train_step") if recompiles else None,
        "run_dir": s.run_dir,
        "phase_ms": phase_ms,
        "final_metrics": last_row,
        "state": state,
    }
