"""Static-analysis subsystem tests (src/repro/analysis/, docs/analysis.md).

Three layers:
  * HLO collective auditor — parser + budget checks on synthetic HLO text
    (fast, in-process), and the full `python -m repro.analysis audit
    --self-test` matrix in an 8-device subprocess (marked multidevice):
    dense / device-parallel / ZeRO-sharded budgets must pass and the
    PLANTED extra all-reduce must be caught.
  * RPR0xx AST lint — a positive and a negative fixture per rule, plus
    noqa suppression and the CLI's exit codes.
  * runtime sanitizers — the recompilation counter must trip on a
    shape-polymorphic step and stay quiet on a monomorphic one; the
    trainer's --sanitize path reports exactly one steady-state compile.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import lint_source
from repro.analysis.hlo_audit import (
    CollectiveBudget,
    audit_text,
    parse_collectives,
)

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


# ---------------------------------------------------------------------------
# HLO parser
# ---------------------------------------------------------------------------

_HLO = """\
HloModule jit_step, entry_computation_layout={(f32[8,16]{1,0})->f32[]}

ENTRY %main (p0: f32[8,16]) -> f32[] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %ar.1 = f32[8,16]{1,0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
  %ag.1 = f32[32,16]{1,0} all-gather(%p0), dimensions={0}
  %ars = f32[2]{0} all-reduce-start(%small), to_apply=%add
  %ard = f32[2]{0} all-reduce-done(%ars)
  %cp = (f32[4]{0}, u8[128]{0}) collective-permute-start(%p0)
}
"""


def test_parse_collectives_kinds_and_shapes():
    ops = parse_collectives(_HLO)
    kinds = [o.kind for o in ops]
    # -start counts once, -done (payload-free completion) never
    assert kinds == ["all-reduce", "all-gather", "all-reduce",
                     "collective-permute"]
    by_kind = {}
    for o in ops:
        by_kind.setdefault(o.kind, []).append(o)
    assert [o.bytes for o in by_kind["all-reduce"]] == [8 * 16 * 4, 2 * 4]
    assert by_kind["all-gather"][0].bytes == 32 * 16 * 4
    # tuple shapes sum their components: f32[4] + u8[128]
    assert by_kind["collective-permute"][0].bytes == 4 * 4 + 128


def test_parse_collectives_clean_module():
    assert parse_collectives("ENTRY %main {\n  %x = f32[4]{0} add(%a, %b)\n}") == []


# ---------------------------------------------------------------------------
# budgets
# ---------------------------------------------------------------------------

def _budget(phase="global_dense", reduce_ops=2, gather_ops=0,
            reduce_bytes=1 << 20, gather_bytes=1 << 20):
    return CollectiveBudget(
        phase=phase, max_reduce_ops=reduce_ops, max_gather_ops=gather_ops,
        max_reduce_bytes=reduce_bytes, max_gather_bytes=gather_bytes)


def test_audit_flags_forbidden_kind_and_excess_gather():
    rep = audit_text(_HLO, _budget(), name="synthetic")
    assert not rep.passed
    msgs = "\n".join(rep.violations)
    assert "collective-permute" in msgs          # forbidden kind
    assert "gather ops exceed" in msgs           # 1 > 0
    assert rep.counts["all-reduce"] == 2


def test_audit_catches_planted_extra_all_reduce():
    """One reduction round budgeted, two compiled: the stray one trips."""
    rep = audit_text(
        "  %a = f32[64]{0} all-reduce(%x), to_apply=%add\n"
        "  %b = f32[64]{0} all-reduce(%y), to_apply=%add\n",
        _budget(reduce_ops=1), name="planted")
    assert not rep.passed
    assert any("exceed the budget of 1" in v for v in rep.violations)


def test_audit_catches_payload_overrun():
    rep = audit_text(
        "  %a = f32[1024]{0} all-reduce(%x), to_apply=%add\n",
        _budget(reduce_bytes=1024), name="fat")
    assert not rep.passed
    assert any("payload" in v for v in rep.violations)


def test_audit_passes_within_budget():
    rep = audit_text(
        "  %a = f32[64]{0} all-reduce(%x), to_apply=%add\n",
        _budget(reduce_ops=1), name="ok")
    assert rep.passed
    assert rep.to_json()["passed"] is True


def test_phase_budget_shapes():
    from benchmarks.comm import phase_collective_budget

    local = phase_collective_budget("local", n_param_leaves=10,
                                    payload_bytes=1000)
    assert local["max_reduce_ops"] == 0 and local["max_gather_ops"] == 0
    dense = phase_collective_budget("global_dense", n_param_leaves=10,
                                    payload_bytes=1000)
    assert dense["max_reduce_ops"] == 12       # 10 leaves + 2 metric scalars
    assert dense["max_gather_ops"] == 0
    zero = phase_collective_budget("global_zero", n_param_leaves=10,
                                   payload_bytes=1000)
    assert zero["max_gather_ops"] == 12
    with pytest.raises(ValueError, match="phase"):
        phase_collective_budget("warmup", n_param_leaves=1, payload_bytes=1)


def test_budget_for_phase_derives_from_pytree():
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    b = CollectiveBudget.for_phase("global_dense", params)
    assert b.max_reduce_ops == 2 + 2
    assert b.max_reduce_bytes >= (8 * 4 + 4) * 4
    assert b.max_gather_ops == 0


# ---------------------------------------------------------------------------
# RPR0xx lint: a positive and a negative fixture per rule
# ---------------------------------------------------------------------------

def _lint(src):
    return lint_source(textwrap.dedent(src), "fixture.py")


def _rules(src):
    return [f.rule for f in _lint(src)]


def test_rpr001_key_reuse_positive():
    assert _rules("""
        import jax
        def f(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b
    """) == ["RPR001"]


def test_rpr001_split_negative():
    assert _rules("""
        import jax
        def f(key):
            k1, k2 = jax.random.split(key)
            return jax.random.normal(k1, (3,)) + jax.random.uniform(k2, (3,))
    """) == []


def test_rpr001_use_after_split_positive():
    assert _rules("""
        import jax
        def f(key):
            k1, _ = jax.random.split(key)
            return jax.random.normal(key, (3,))
    """) == ["RPR001"]


def test_rpr001_fold_in_loop_negative():
    assert _rules("""
        import jax
        def f(key, n):
            out = 0.0
            for t in range(n):
                out += jax.random.normal(jax.random.fold_in(key, t), ())
            return out
    """) == []


def test_rpr001_loop_invariant_key_positive():
    assert _rules("""
        import jax
        def f(key, n):
            out = 0.0
            for _ in range(n):
                out += jax.random.normal(key, ())
            return out
    """) == ["RPR001"]


def test_rpr001_branches_negative():
    assert _rules("""
        import jax
        def f(key, flag):
            if flag:
                return jax.random.normal(key, ())
            return jax.random.uniform(key, ())
    """) == []


def test_rpr002_host_sync_in_jitted_positive():
    assert _rules("""
        import jax, jax.numpy as jnp
        def step(x):
            return float(jnp.sum(x))
        jstep = jax.jit(step)
    """) == ["RPR002"]


def test_rpr002_reachable_via_callback_positive():
    assert _rules("""
        import jax
        def inner(x):
            return x.item()
        def step(x):
            return inner(x)
        jstep = jax.jit(step)
    """) == ["RPR002"]


def test_rpr002_unreachable_negative():
    assert _rules("""
        import numpy as np
        def logger(x):
            return float(np.asarray(x).mean())
    """) == []


def test_rpr002_noqa_suppression():
    assert _rules("""
        import jax, jax.numpy as jnp
        def step(x):
            return float(jnp.sum(x))  # noqa: RPR002
        jstep = jax.jit(step)
    """) == []


def test_rpr003_traced_branch_positive():
    assert _rules("""
        import jax, jax.numpy as jnp
        def step(x):
            y = jnp.sum(x)
            if y > 0:
                return y
            return -y
        jstep = jax.jit(step)
    """) == ["RPR003"]


def test_rpr003_static_branch_negative():
    assert _rules("""
        import jax, jax.numpy as jnp
        def step(x, accum):
            if accum:
                x = x + 1
            return jnp.sum(x)
        jstep = jax.jit(step)
    """) == []


def test_rpr004_mutable_default_positive():
    found = _rules("""
        import dataclasses
        @dataclasses.dataclass
        class Config:
            layers: list = []
        def f(xs=[]):
            return xs
    """)
    assert found == ["RPR004", "RPR004"]


def test_rpr004_factory_negative():
    assert _rules("""
        import dataclasses
        @dataclasses.dataclass
        class Config:
            layers: list = dataclasses.field(default_factory=list)
        def f(xs=()):
            return xs
    """) == []


def test_lint_cli_exit_codes(tmp_path, capsys):
    from repro.analysis.__main__ import main

    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent("""
        import jax, jax.numpy as jnp
        def step(x):
            return float(jnp.sum(x))
        jstep = jax.jit(step)
    """))
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert main(["lint", str(bad)]) == 1
    assert main(["lint", str(clean)]) == 0
    assert main(["lint", "--select", "RPR999", str(bad)]) == 2
    out = json.loads(
        (capsys.readouterr(), main(["lint", "--json", str(bad)]),
         capsys.readouterr())[2].out)
    assert out[0]["rule"] == "RPR002"
    assert out[0]["path"].endswith("bad.py")


def test_lint_src_is_clean():
    """The repo's own source must stay RPR-clean (sanctioned sync points
    carry noqa with a reason; see docs/analysis.md)."""
    from repro.analysis.lint import lint_paths

    findings = lint_paths([os.path.join(SRC, "repro")])
    assert findings == [], "\n".join(str(f) for f in findings)


# ---------------------------------------------------------------------------
# runtime sanitizers
# ---------------------------------------------------------------------------

def test_recompilation_counter_trips_on_shape_polymorphic_step():
    from repro.analysis import sanitize as SAN

    def shape_poly_probe(x):
        return jnp.sum(x * 2.0)

    probe = jax.jit(shape_poly_probe)
    prev_flag = jax.config.jax_log_compiles
    with SAN.RecompilationCounter() as rc:
        probe(jnp.ones((4,)))
        probe(jnp.ones((4,)))          # cache hit: no second compile
        assert rc.count("shape_poly_probe") == 1
        rc.assert_steady_state("shape_poly_probe")
        probe(jnp.ones((8,)))          # new shape -> silent recompile
    assert rc.count("shape_poly_probe") == 2
    with pytest.raises(SAN.SanitizeError, match="compiled 2 times"):
        rc.assert_steady_state("shape_poly_probe")
    assert jax.config.jax_log_compiles == prev_flag   # restored on exit


def test_debug_nans_restores_config():
    from repro.analysis import sanitize as SAN

    prev = jax.config.jax_debug_nans
    with SAN.debug_nans():
        assert jax.config.jax_debug_nans
    assert jax.config.jax_debug_nans == prev
    with SAN.debug_nans(enabled=False):
        assert jax.config.jax_debug_nans == prev


def test_transfer_guard_context_is_composable():
    from repro.analysis import sanitize as SAN

    # On the CPU backend device buffers ARE host buffers, so the guard
    # blocks nothing here (armed on real accelerators) — but the context
    # must nest and restore cleanly around real work.
    with SAN.no_implicit_host_sync():
        with SAN.no_implicit_host_sync(enabled=False):
            pass
        assert float(jnp.ones(()).sum()) == 1.0


def test_trainer_sanitize_counts_one_steady_state_compile():
    from repro.configs.base import ModelConfig
    from repro.data.pipeline import MarkovCorpus
    from repro.train.trainer import TrainSettings, run_training

    nano = ModelConfig(
        name="nano", family="lm", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16, mlp_gated=False,
        act="gelu", dtype="float32", param_dtype="float32", vocab_pad_to=64,
    )
    corpus = MarkovCorpus(nano.vocab_size, branch=4, seed=7)
    s = TrainSettings(algorithm="dsm", n_workers=2, tau=2, steps=3,
                      b_micro=2, seq=32, eval_every=3, sanitize=True)
    r = run_training(nano, s, corpus)
    assert r["step_compiles"] == 1


# ---------------------------------------------------------------------------
# the full audit matrix: 8-device subprocess (the CI gate)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_audit_cli_8dev_matrix_and_self_test():
    """`python -m repro.analysis audit --json --self-test` on a forced
    8-device host: dense / device-parallel / ZeRO-sharded budgets pass,
    the local phase compiles ZERO collectives, and the planted extra
    all-reduce variant is caught (reported failed, overall exit 0)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)   # the CLI forces the device count itself
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get(
        "PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "audit", "--json",
         "--self-test"],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    payload = json.loads(proc.stdout)
    assert payload["n_devices"] == 8
    assert not payload["degenerate"]
    assert payload["passed"]
    by_name = {r["name"]: r for r in payload["reports"]}
    for name in ("dense", "device_parallel", "zero_sharded"):
        assert by_name[name]["passed"], by_name[name]
    assert by_name["local_phase"]["counts"] == {}, by_name["local_phase"]
    # the ZeRO step genuinely gathers (reduce lowers as all-reduce on CPU)
    assert by_name["zero_sharded"]["counts"].get("all-gather", 0) > 0
    planted = by_name["self_test_planted_all_reduce"]
    assert planted["passed"] is False
    assert any("exceed" in v for v in planted["violations"]), planted
