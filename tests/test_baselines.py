"""Baseline algorithms: recursions match their paper pseudocode."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adamw, constant, sgd
from repro.core import baselines as BL


def quad_loss(center):
    def loss(params, batch):
        tgt = center + batch["noise"]
        return 0.5 * jnp.mean(jnp.sum((params["x"][None] - tgt) ** 2, axis=-1))

    return loss


def _batch(key, W, tau, B, d):
    return {"noise": 0.1 * jax.random.normal(key, (W, tau, B, d))}


def test_slowmo_recursion():
    """Alg. 5: u <- beta*u + Delta/gamma ; x <- x0 - alpha*gamma*u."""
    d, beta, alpha, gamma = 8, 0.6, 0.9, 0.05
    key = jax.random.PRNGKey(0)
    loss = quad_loss(jax.random.normal(key, (d,)))
    init, step = BL.slowmo(loss, sgd(), tau=3, schedule=constant(gamma),
                           beta=beta, alpha=alpha)
    state = init({"x": jnp.zeros((d,))}, 2)
    u_manual = jnp.zeros((d,))
    x_manual = jnp.zeros((d,))
    for t in range(4):
        key, sub = jax.random.split(key)
        batch = _batch(sub, 2, 3, 4, d)
        # replay the local phase manually
        xs = jnp.broadcast_to(x_manual, (2, d))
        for k in range(3):
            g = jax.vmap(
                lambda p, mb: jax.grad(loss)({"x": p}, mb)["x"]
            )(xs, jax.tree.map(lambda a: a[:, k], batch))
            xs = xs - gamma * g
        delta = x_manual - xs.mean(0)
        u_manual = beta * u_manual + delta / gamma
        x_manual = x_manual - alpha * gamma * u_manual
        state, _ = step(state, batch)
        np.testing.assert_allclose(
            np.asarray(state.x0["x"]), np.asarray(x_manual), rtol=1e-4, atol=1e-6
        )


def test_local_avg_is_mean():
    d = 8
    key = jax.random.PRNGKey(1)
    loss = quad_loss(jax.random.normal(key, (d,)))
    init, step = BL.local_avg(loss, sgd(), tau=2, schedule=constant(0.05))
    state = init({"x": jnp.zeros((d,))}, 4)
    batch = _batch(key, 4, 2, 4, d)
    new_state, _ = step(state, batch)
    # x0_new must equal the mean of the (replayed) local iterates
    xs = jnp.zeros((4, d))
    for k in range(2):
        g = jax.vmap(lambda p, mb: jax.grad(loss)({"x": p}, mb)["x"])(
            xs, jax.tree.map(lambda a: a[:, k], batch))
        xs = xs - 0.05 * g
    np.testing.assert_allclose(
        np.asarray(new_state.x0["x"]), np.asarray(xs.mean(0)), rtol=1e-5, atol=1e-7
    )


def test_global_adamw_first_step():
    """Alg. 7 with t=0: x <- x0 - eta*gamma*(g/( |g| + eps) )  (bias-corrected)."""
    d = 8
    key = jax.random.PRNGKey(2)
    loss = quad_loss(jax.random.normal(key, (d,)))
    init, step = BL.global_adamw(loss, sgd(), tau=2, schedule=constant(0.05),
                                 eta=1.0, b1=0.9, b2=0.95, weight_decay=0.0)
    state = init({"x": jnp.zeros((d,))}, 2)
    batch = _batch(key, 2, 2, 4, d)
    new_state, _ = step(state, batch)
    # first-step AdamW reduces to sign-like g/|g| (bias corrections cancel)
    moves = np.abs(np.asarray(new_state.x0["x"]))
    assert np.all(moves <= 1.0 * 0.05 * (1 + 1e-4))
    assert np.all(moves >= 0.04)  # |update| ~ eta*gamma unless g ~ 0


def test_perstep_dp_equals_single_worker_adamw():
    """Per-step DP with W workers == one AdamW on the averaged gradient."""
    d = 8
    key = jax.random.PRNGKey(3)
    loss = quad_loss(jax.random.normal(key, (d,)))
    base = adamw(weight_decay=0.0)
    init, step = BL.make_perstep_dp_step(loss, base, tau=2, schedule=constant(0.01))
    state = init({"x": jnp.zeros((d,))}, 4)
    batch = _batch(key, 4, 2, 4, d)
    new_state, _ = step(state, batch)

    params = {"x": jnp.zeros((d,))}
    bs = base.init(params)
    for k in range(2):
        gs = jax.vmap(lambda mb: jax.grad(loss)(params, mb))(
            jax.tree.map(lambda a: a[:, k], batch))
        g = jax.tree.map(lambda x: x.mean(0), gs)
        dirn, bs = base.direction(g, bs, params, jnp.int32(k))
        params = jax.tree.map(lambda x, dd: x - 0.01 * dd, params, dirn)
    np.testing.assert_allclose(
        np.asarray(new_state.params["x"]), np.asarray(params["x"]),
        rtol=1e-5, atol=1e-7,
    )


def test_mv_signsgd_runs_and_is_sign_bounded():
    d = 8
    key = jax.random.PRNGKey(4)
    loss = quad_loss(jax.random.normal(key, (d,)))
    init, step = BL.make_mv_signsgd_step(loss, tau=2, gamma=0.05, eta=0.01)
    state = init({"x": jnp.zeros((d,))}, 4)
    batch = _batch(key, 4, 2, 4, d)
    new_state, m = step(state, batch, jax.random.PRNGKey(9))
    assert np.isfinite(float(m["loss"]))
    assert np.all(np.abs(np.asarray(new_state.x["x"])) <= 0.01 + 1e-7)
