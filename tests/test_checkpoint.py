"""Checkpoint subsystem: atomicity, rotation, dtype safety, full-DSMState
round trips (bf16 momentum, ZeRO-sharded layout) on 1 and 8 devices."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as CK
from repro.core import DSMConfig, adamw, constant, dsm_init, make_dsm_step

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _tree():
    return {
        "w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16) * 1.5,
                   "t": jnp.asarray(7, jnp.int32)},
    }


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# single-checkpoint primitives
# ---------------------------------------------------------------------------

def test_save_is_complete_and_extra_meta_roundtrips(tmp_path):
    base = str(tmp_path / "ck")
    assert not CK.is_complete(base)
    CK.save(base, _tree(), step=5, extra={"history": [1.0, 2.0]})
    assert CK.is_complete(base)
    restored, step = CK.restore(base, _tree())
    assert step == 5
    _assert_trees_equal(restored, _tree())
    assert CK.load_meta(base)["extra"] == {"history": [1.0, 2.0]}
    # no stray temp files survive the save
    assert not [p for p in os.listdir(tmp_path) if ".tmp." in p]


def test_restore_rejects_dtype_drift(tmp_path):
    base = str(tmp_path / "ck")
    CK.save(base, _tree())
    drifted = _tree()
    drifted["w"] = drifted["w"].astype(jnp.float16)
    with pytest.raises(ValueError, match="dtype mismatch"):
        CK.restore(base, drifted)
    # bf16 <-> f32 drift is caught in BOTH directions (the bf16 tag)
    drifted = _tree()
    drifted["nested"]["b"] = drifted["nested"]["b"].astype(jnp.float32)
    with pytest.raises(ValueError, match="dtype mismatch"):
        CK.restore(base, drifted)


def test_restore_rejects_shape_drift_and_missing_leaf(tmp_path):
    base = str(tmp_path / "ck")
    CK.save(base, _tree())
    drifted = _tree()
    drifted["w"] = jnp.zeros((3, 2), jnp.float32)
    with pytest.raises(ValueError, match="shape mismatch"):
        CK.restore(base, drifted)
    grown = _tree()
    grown["new_leaf"] = jnp.zeros(2)
    with pytest.raises(KeyError, match="missing leaf"):
        CK.restore(base, grown)


# ---------------------------------------------------------------------------
# rotated manager: torn writes, retention, latest pointer
# ---------------------------------------------------------------------------

def test_torn_write_is_ignored(tmp_path):
    d = str(tmp_path)
    CK.save_checkpoint(d, _tree(), 1)
    # simulate a kill between the npz and json replaces of step 2: the npz
    # landed but the commit marker did not
    torn = CK.step_path(d, 2)
    np.savez(torn + ".npz", a0=np.zeros(3))
    assert [s for s, _ in CK.list_checkpoints(d)] == [1]
    assert CK.latest_checkpoint(d) == CK.step_path(d, 1)
    got = CK.restore_latest(d, _tree())
    assert got is not None and got[1] == 1
    # ... and the orphaned json case (npz pruned, json left) is also skipped
    orphan = CK.step_path(d, 3)
    with open(orphan + ".json", "w") as f:
        json.dump({"step": 3, "keys": []}, f)
    assert [s for s, _ in CK.list_checkpoints(d)] == [1]


def test_retention_keeps_newest_and_repoints_latest(tmp_path):
    d = str(tmp_path)
    for step in (2, 4, 6, 8, 10):
        CK.save_checkpoint(d, _tree(), step, keep=2)
    assert [s for s, _ in CK.list_checkpoints(d)] == [8, 10]
    assert CK.latest_checkpoint(d) == CK.step_path(d, 10)
    # pruned files are really gone
    assert not os.path.exists(CK.step_path(d, 2) + ".npz")


def test_latest_pointer_falls_back_to_scan(tmp_path):
    d = str(tmp_path)
    CK.save_checkpoint(d, _tree(), 3)
    CK.save_checkpoint(d, _tree(), 7)
    # stale pointer: points at a checkpoint that was deleted by hand
    os.remove(CK.step_path(d, 7) + ".npz")
    os.remove(CK.step_path(d, 7) + ".json")
    assert CK.latest_checkpoint(d) == CK.step_path(d, 3)
    # no checkpoints at all -> None
    os.remove(CK.step_path(d, 3) + ".npz")
    os.remove(CK.step_path(d, 3) + ".json")
    assert CK.latest_checkpoint(d) is None
    assert CK.restore_latest(d, _tree()) is None


def test_restore_latest_empty_dir(tmp_path):
    assert CK.restore_latest(str(tmp_path / "nowhere"), _tree()) is None


# ---------------------------------------------------------------------------
# full DSMState round trips
# ---------------------------------------------------------------------------

def _quad_state_after(n_steps, momentum_dtype=jnp.float32, mesh=None,
                      zero_sharded=False):
    d, n_workers = 16, 2
    key = jax.random.PRNGKey(3)

    def loss(params, batch):
        return 0.5 * jnp.mean(jnp.sum((params["x"][None] - batch["y"]) ** 2,
                                      axis=-1))

    cfg = DSMConfig(tau=2, global_lr=0.5, zero_sharded=zero_sharded)
    step = jax.jit(make_dsm_step(loss, adamw(), cfg, constant(0.05), mesh=mesh))
    state = dsm_init({"x": jnp.zeros((d,))}, adamw(), n_workers,
                     momentum_dtype=momentum_dtype, mesh=mesh,
                     global_sharded=zero_sharded)
    for t in range(n_steps):
        batch = {"y": jax.random.normal(jax.random.fold_in(key, t),
                                        (n_workers, 2, 1, 4, d))}
        state, _ = step(state, batch)
    return state, step, key


def test_full_dsmstate_roundtrip_with_bf16_momentum(tmp_path):
    state, _, _ = _quad_state_after(3, momentum_dtype=jnp.bfloat16)
    assert jax.tree.leaves(state.m)[0].dtype == jnp.bfloat16
    base = str(tmp_path / "ck")
    CK.save(base, state, step=3)
    restored, step = CK.restore(base, state)
    assert step == 3
    _assert_trees_equal(restored, state)  # params, x0, m, base_state, t, inner
    assert int(restored.t) == 3 and int(restored.inner) == 6


def test_dsmstate_roundtrip_zero_sharded_layout(tmp_path):
    """Restore of a ZeRO-sharded state + reshard is bit-exact AND the
    resharded state continues training identically to the original."""
    from repro.distributed import zero as Z
    from repro.launch.mesh import host_training_mesh

    mesh = host_training_mesh(2)
    state, step_fn, key = _quad_state_after(3, mesh=mesh, zero_sharded=True)
    base = str(tmp_path / "ck")
    CK.save(base, state, step=3)
    restored, _ = CK.restore(base, state)
    restored = Z.shard_dsm_state(restored, mesh, global_sharded=True)
    _assert_trees_equal(restored, state)
    batch = {"y": jax.random.normal(jax.random.fold_in(key, 99), (2, 2, 1, 4, 16))}
    cont_a, _ = step_fn(state, batch)
    cont_b, _ = step_fn(restored, batch)
    _assert_trees_equal(cont_a, cont_b)


@pytest.mark.multidevice
def test_sharded_dsmstate_roundtrip_8dev(tmp_path):
    """The npz round trip of a genuinely 8-device-sharded DSMState (worker-
    sharded params, ZeRO-sharded x0/m, bf16 momentum) is exact, and the
    resharded restore continues training bit-identically."""
    script = r"""
import json, sys, tempfile
import jax
import jax.numpy as jnp
import numpy as np
from repro.checkpoint import checkpoint as CK
from repro.core import DSMConfig, adamw, constant, dsm_init, make_dsm_step
from repro.distributed import zero as Z
from repro.launch.mesh import host_training_mesh

d, n_workers = 32, 4
mesh = host_training_mesh(n_workers)
key = jax.random.PRNGKey(3)

def loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"][None] - batch["y"]) ** 2, axis=-1))

cfg = DSMConfig(tau=2, global_lr=0.5, zero_sharded=True)
step = jax.jit(make_dsm_step(loss, adamw(), cfg, constant(0.05), mesh=mesh))
state = dsm_init({"x": jnp.zeros((d,))}, adamw(), n_workers,
                 momentum_dtype=jnp.bfloat16, mesh=mesh, global_sharded=True)
for t in range(3):
    batch = {"y": jax.random.normal(jax.random.fold_in(key, t),
                                    (n_workers, 2, 1, 4, d))}
    state, _ = step(state, batch)

n_shards = len({dev for l in jax.tree.leaves(state.x0)
                for dev in l.sharding.device_set})
with tempfile.TemporaryDirectory() as ckdir:
    CK.save_checkpoint(ckdir, state, 3)
    restored, step_no, _ = CK.restore_latest(ckdir, state)
    restored = Z.shard_dsm_state(restored, mesh, global_sharded=True)
    exact = all(
        bool(jnp.array_equal(a, b)) and a.dtype == b.dtype
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)))
    batch = {"y": jax.random.normal(jax.random.fold_in(key, 99),
                                    (n_workers, 2, 1, 4, d))}
    ca, _ = step(state, batch)
    cb, _ = step(restored, batch)
    cont = all(bool(jnp.array_equal(a, b)) for a, b in
               zip(jax.tree.leaves(ca), jax.tree.leaves(cb)))
print("RESULT", json.dumps({
    "devices": jax.device_count(), "x0_devices": n_shards,
    "step": step_no, "exact": exact, "continues": cont,
    "m_dtype": str(jax.tree.leaves(restored.m)[0].dtype),
}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rec = json.loads(line[len("RESULT "):])
    assert rec["devices"] == 8
    assert rec["x0_devices"] == 8  # x0 really was sharded over all ranks
    assert rec["step"] == 3
    assert rec["exact"] and rec["continues"]
    assert rec["m_dtype"] == "bfloat16"
