"""Config/spec invariants across ALL archs x input shapes (catches config
drift before it reaches the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    ARCH_IDS,
    INPUT_SHAPES,
    PAPER_ARCH_IDS,
    arch_supports_shape,
    load_arch,
)
from repro.configs import specs as S
from repro.core.schedules import cosine_with_warmup
from benchmarks.comm import bytes_per_outer_step


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_batch_specs_consistent(arch_id):
    mod = load_arch(arch_id)
    cfg, topo = mod.FULL, mod.TOPO
    shape = INPUT_SHAPES["train_4k"]
    for W in (topo.n_workers_single, topo.n_workers_multi):
        batch = S.train_batch_specs(cfg, topo, shape, W)
        toks = batch["tokens"]
        Wb, tau, acc, bm = toks.shape[:4]
        assert (Wb, tau, acc) == (W, topo.tau, topo.grad_accum)
        assert W * acc * bm == shape.global_batch
        if cfg.family == "vlm":
            assert toks.shape[-1] + cfg.n_patches == shape.seq_len
            assert batch["patches"].shape[-2:] == (cfg.n_patches, cfg.d_model)
        elif cfg.family == "encdec":
            assert batch["frames"].shape[-2:] == (cfg.enc_len, cfg.d_model)
        else:
            assert toks.shape[-1] == shape.seq_len


@pytest.mark.parametrize("arch_id", ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["prefill_32k", "decode_32k", "long_500k"])
def test_serve_specs_build(arch_id, shape_name):
    mod = load_arch(arch_id)
    cfg, topo = mod.FULL, mod.TOPO
    if not arch_supports_shape(cfg, topo, shape_name):
        pytest.skip("spec-sanctioned long-context skip (DESIGN.md)")
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "prefill":
        b = S.prefill_batch_specs(cfg, shape)
        assert b["tokens"].shape[0] == shape.global_batch
    else:
        d = S.decode_specs(cfg, shape)
        assert d["tokens"].shape == (shape.global_batch,)
        # cache tree must be non-empty and finite-sized
        leaves = jax.tree.leaves(d["cache"])
        assert leaves, arch_id
        total = sum(np.prod(l.shape) * l.dtype.itemsize for l in leaves)
        assert total > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS + PAPER_ARCH_IDS)
def test_vocab_padding_divides_model_axis(arch_id):
    cfg = load_arch(arch_id).FULL
    assert cfg.padded_vocab % 16 == 0  # model-axis shardable
    assert cfg.padded_vocab >= cfg.vocab_size


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_pattern_covers_layers(arch_id):
    cfg = load_arch(arch_id).FULL
    kinds = cfg.layer_kinds()
    assert len(kinds) == cfg.n_layers
    assert cfg.n_scan_blocks * len(cfg.pattern) + cfg.n_rem_layers == cfg.n_layers


def test_cosine_schedule_endpoints():
    sched = cosine_with_warmup(1e-3, total_steps=1000, warmup_steps=100,
                               final_frac=0.05)
    assert float(sched(0)) < 2e-5  # warmup start
    np.testing.assert_allclose(float(sched(100)), 1e-3, rtol=0.02)  # peak
    np.testing.assert_allclose(float(sched(999)), 5e-5, rtol=0.05)  # floor
    # monotone decay after warmup
    vals = [float(sched(t)) for t in range(100, 1000, 100)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_comm_model_reduction_matches_tau():
    r_dsm = bytes_per_outer_step("gpt2_small", "dsm", tau=12)
    r_ps = bytes_per_outer_step("gpt2_small", "perstep", tau=12)
    assert r_ps["wire_bytes_per_outer"] == 12 * r_dsm["wire_bytes_per_outer"]
    np.testing.assert_allclose(r_dsm["reduction_vs_perstep"], 12.0)


def test_momentum_dtype_knob():
    from repro.core import dsm_init, sgd

    params = {"w": jnp.zeros((8,), jnp.bfloat16)}
    st = dsm_init(params, sgd(), 2, momentum_dtype=jnp.bfloat16)
    assert st.m["w"].dtype == jnp.bfloat16
    st32 = dsm_init(params, sgd(), 2)
    assert st32.m["w"].dtype == jnp.float32
