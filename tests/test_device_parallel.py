"""Device-parallel local phase (DSMConfig.device_parallel_local) tests.

The multi-device equivalence tests run in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main pytest
process must keep seeing one CPU device; XLA fixes the device count at
first jax use) and are marked ``multidevice`` — CI runs them in their own
job.  Everything else runs in-process on the 1-device degenerate mesh
(worker=1), which exercises the identical shard_map code path cheaply.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DSMConfig,
    constant,
    dsm_init,
    make_dsm_step,
    make_local_phase,
    sgd,
)
from repro.launch.mesh import host_training_mesh

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


# ---------------------------------------------------------------------------
# factory contracts
# ---------------------------------------------------------------------------

def test_make_local_phase_requires_worker_mesh():
    with pytest.raises(ValueError, match="worker"):
        make_local_phase(lambda p, b: 0.0, sgd(), device_parallel=True, mesh=None)


def test_local_phase_returns_per_worker_losses():
    """losses come back unreduced (tau, W): the worker mean must happen
    OUTSIDE the (collective-free) local phase."""

    def loss(params, mb):
        return jnp.mean((params["x"] - mb) ** 2)

    lp = make_local_phase(loss, sgd(), accum=False)
    params_w = {"x": jnp.zeros((3, 4))}
    batch = jnp.ones((3, 2, 5, 4))  # (W=3, tau=2, B=5, d)
    _, _, losses = lp(params_w, (), batch, jnp.float32(0.1), jnp.int32(0))
    assert losses.shape == (2, 3)


def test_host_training_mesh_rejects_indivisible_worker_count(monkeypatch):
    monkeypatch.setattr(jax, "devices", lambda: [object() for _ in range(8)])
    with pytest.raises(ValueError, match="does not divide"):
        host_training_mesh(3)


# ---------------------------------------------------------------------------
# 1-device degenerate mesh: device_parallel_local == vmapped, in-process
# ---------------------------------------------------------------------------

def _quad_setup(device_parallel, zero_sharded, use_kernel, steps=3):
    d = 48
    key = jax.random.PRNGKey(7)
    center = jax.random.normal(key, (d,))

    def loss(params, batch):
        tgt = center + batch["noise"]
        return 0.5 * jnp.mean(jnp.sum((params["x"][None] - tgt) ** 2, axis=-1))

    mesh = host_training_mesh(2) if (device_parallel or zero_sharded) else None
    cfg = DSMConfig(tau=2, global_lr=0.7, use_kernel=use_kernel,
                    zero_sharded=zero_sharded,
                    device_parallel_local=device_parallel)
    step = jax.jit(make_dsm_step(loss, sgd(), cfg, constant(0.05), mesh=mesh))
    state = dsm_init({"x": jnp.zeros((d,))}, sgd(), n_workers=2, mesh=mesh,
                     global_sharded=zero_sharded)
    losses = []
    for t in range(steps):
        batch = {"noise": 0.1 * jax.random.normal(
            jax.random.fold_in(key, t), (2, 2, 1, 4, d))}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return state, losses


@pytest.mark.parametrize("zero_sharded", [False, True])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_device_parallel_single_device_matches(zero_sharded, use_kernel):
    ref, ref_losses = _quad_setup(False, False, use_kernel)
    dp, dp_losses = _quad_setup(True, zero_sharded, use_kernel)
    np.testing.assert_allclose(np.asarray(dp.x0["x"]), np.asarray(ref.x0["x"]),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dp.m["x"]), np.asarray(ref.m["x"]),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(dp_losses, ref_losses, rtol=0, atol=1e-6)


def test_trainer_device_parallel_wiring():
    """run_training hoists ONE mesh for any mesh-consuming flag and threads
    device_parallel_local through DSM and the shared-local-phase baselines."""
    from repro.configs.base import ModelConfig
    from repro.data.pipeline import MarkovCorpus
    from repro.train.trainer import TrainSettings, run_training

    nano = ModelConfig(
        name="nano", family="lm", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16, mlp_gated=False,
        act="gelu", dtype="float32", param_dtype="float32", vocab_pad_to=64,
    )
    corpus = MarkovCorpus(nano.vocab_size, branch=4, seed=7)
    for algo in ("dsm", "slowmo"):
        s = TrainSettings(algorithm=algo, n_workers=2, tau=2, steps=2,
                          b_micro=2, seq=32, eval_every=2,
                          device_parallel_local=True)
        r = run_training(nano, s, corpus)
        assert np.isfinite(r["final_eval"]), algo


# ---------------------------------------------------------------------------
# comm model: the layout's accounting
# ---------------------------------------------------------------------------

def test_comm_model_reports_local_compute_deduplication():
    from benchmarks.comm import bytes_per_outer_step

    rep = bytes_per_outer_step("gpt2_small", "dsm", tau=12, n_workers=8)
    dp = bytes_per_outer_step("gpt2_small", "dsm", tau=12, n_workers=8,
                              device_parallel=True)
    assert rep["local_step_flops_replication"] == 8
    assert dp["local_step_flops_replication"] == 1
    # the local phase was always collective-free: wire volume must not move
    assert dp["wire_bytes_per_outer"] == rep["wire_bytes_per_outer"]
    assert dp["comm_rounds_per_outer"] == rep["comm_rounds_per_outer"]
    # non-local-step algorithms don't carry the field
    ps = bytes_per_outer_step("gpt2_small", "perstep", tau=12)
    assert "local_step_flops_replication" not in ps


# ---------------------------------------------------------------------------
# 8-device equivalence: device-parallel == vmapped trajectories, and the
# compiled local phase contains no inter-worker collectives
# ---------------------------------------------------------------------------

_EQUIV_SCRIPT = r"""
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import (DSMConfig, constant, dsm_init, make_dsm_step,
                        make_local_phase, get_base_optimizer)
from repro.core import baselines as BL
from repro.data.pipeline import MarkovCorpus, dsm_batches
from repro.launch.mesh import host_training_mesh
from repro.models import transformer as T

NANO = ModelConfig(
    name="nano", family="lm", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=64, head_dim=16, mlp_gated=False, act="gelu",
    dtype="float32", param_dtype="float32", vocab_pad_to=64,
)
W, TAU, STEPS = 4, 2, 5
loss = lambda p, mb: T.loss_fn(p, mb, NANO, remat=False)
base = get_base_optimizer("adamw")


def run(device_parallel, zero_sharded, use_kernel):
    mesh = host_training_mesh(W) if (device_parallel or zero_sharded) else None
    cfg = DSMConfig(tau=TAU, global_lr=1.0, zero_sharded=zero_sharded,
                    use_kernel=use_kernel, device_parallel_local=device_parallel)
    step = jax.jit(make_dsm_step(loss, base, cfg, constant(2e-2), mesh=mesh))
    params = T.init_params(jax.random.PRNGKey(3), NANO)
    state = dsm_init(params, base, W, mesh=mesh, global_sharded=zero_sharded)
    # heterogeneous=True: each worker consumes its own stream (paper's D_i)
    batches = dsm_batches(MarkovCorpus(64, seed=1), W, TAU, 1, 2, 32, seed=3,
                          heterogeneous=True)
    hist = []
    for _ in range(STEPS):
        state, m = step(state, jax.tree.map(jnp.asarray, next(batches)))
        hist.append(float(m["loss"]))
    return state, hist


def maxdiff(a, b):
    return max(float(jnp.abs(x - y).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


rec = {"n_devices": jax.device_count()}

for name, use_kernel in (("jnp", False), ("kernel", True)):
    ref, href = run(False, False, use_kernel)
    out = {}
    for tag, zero_sharded in (("plain", False), ("zero", True)):
        dp, hdp = run(True, zero_sharded, use_kernel)
        leaf = jax.tree.leaves(dp.params)[0]
        shard_elems = int(np.prod(leaf.sharding.shard_shape(leaf.shape)))
        out[tag] = {
            "x0": maxdiff(ref.x0, dp.x0),
            "m": maxdiff(ref.m, dp.m),
            "loss": max(abs(a - b) for a, b in zip(href, hdp)),
            "param_shard_frac": shard_elems / leaf.size,
        }
    rec[name] = out

# the shared local phase serves the baselines too: slowmo dp == vmapped
def run_slowmo(device_parallel):
    mesh = host_training_mesh(W) if device_parallel else None
    init, step = BL.slowmo(loss, base, TAU, constant(2e-2), beta=0.5,
                           device_parallel=device_parallel, mesh=mesh)
    step = jax.jit(step)
    state = init(T.init_params(jax.random.PRNGKey(3), NANO), W)
    batches = dsm_batches(MarkovCorpus(64, seed=1), W, TAU, 1, 2, 32, seed=3)
    hist = []
    for _ in range(STEPS):
        batch = jax.tree.map(lambda x: jnp.asarray(x)[:, :, 0], next(batches))
        state, m = step(state, batch)
        hist.append(float(m["loss"]))
    return state, hist

sref, shref = run_slowmo(False)
sdp, shdp = run_slowmo(True)
rec["slowmo"] = {
    "x0": maxdiff(sref.x0, sdp.x0),
    "loss": max(abs(a - b) for a, b in zip(shref, shdp)),
}

# compiled device-parallel local phase: ZERO inter-worker collectives,
# checked by the HLO auditor against the "local" phase budget
from repro.analysis.hlo_audit import CollectiveBudget, audit_jitted

mesh = host_training_mesh(W)
lp = make_local_phase(loss, base, accum=True, device_parallel=True, mesh=mesh)
params = T.init_params(jax.random.PRNGKey(3), NANO)
state = dsm_init(params, base, W, mesh=mesh, global_sharded=False)
batch = jax.tree.map(jnp.asarray, next(
    dsm_batches(MarkovCorpus(64, seed=1), W, TAU, 1, 2, 32, seed=3)))
rec["local_phase_audit"] = audit_jitted(
    lp, (state.params, state.base_state, batch, jnp.float32(2e-2),
         jnp.int32(0)),
    CollectiveBudget.for_phase("local", state.x0),
    name="local_phase").to_json()

# ... while one full outer step DOES communicate — within the dense global
# budget (sanity: the local check is not vacuously passing on
# collective-free whole-step HLO)
cfg = DSMConfig(tau=TAU, device_parallel_local=True)
rec["outer_step_audit"] = audit_jitted(
    make_dsm_step(loss, base, cfg, constant(2e-2), mesh=mesh),
    (state, batch),
    CollectiveBudget.for_phase("global_dense", state.x0),
    name="outer_step").to_json()

print("RESULT " + json.dumps(rec))
"""


@pytest.mark.multidevice
def test_device_parallel_matches_vmapped_8dev():
    """device_parallel_local == vmapped x0/m/loss trajectories to 1e-5 over
    5 outer steps on a forced 8-device host (worker=4, zero=2), for the jnp
    and fused-kernel global paths, with and without the ZeRO-sharded global
    step, heterogeneous per-worker batches; and the compiled local phase
    contains no inter-worker collective ops."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _EQUIV_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rec = json.loads(line[len("RESULT "):])
    assert rec["n_devices"] == 8
    for path in ("jnp", "kernel"):
        for tag in ("plain", "zero"):
            r = rec[path][tag]
            assert r["x0"] <= 1e-5, (path, tag, rec)
            assert r["m"] <= 1e-5, (path, tag, rec)
            assert r["loss"] <= 1e-5, (path, tag, rec)
            # per-worker params genuinely live in 1/W shards
            assert abs(r["param_shard_frac"] - 0.25) < 1e-9, (path, tag, rec)
    assert rec["slowmo"]["x0"] <= 1e-5, rec
    assert rec["slowmo"]["loss"] <= 1e-5, rec
    lp_audit, os_audit = rec["local_phase_audit"], rec["outer_step_audit"]
    assert lp_audit["passed"], lp_audit
    assert lp_audit["counts"] == {}, lp_audit  # truly collective-free
    assert os_audit["passed"], os_audit
    assert os_audit["counts"] != {}, os_audit  # the ONE reduction round


# ---------------------------------------------------------------------------
# heterogeneous batches under the sharded layout: every worker's shard is
# its own stream, not a replica
# ---------------------------------------------------------------------------

_HET_SCRIPT = r"""
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data.pipeline import MarkovCorpus, dsm_batches
from repro.launch.mesh import host_training_mesh

W, STEPS = 4, 3
mesh = host_training_mesh(W)
sh = NamedSharding(mesh, P("worker"))


def worker_blocks(tokens):
    arr = jax.device_put(jnp.asarray(tokens), sh)
    blocks = {}
    for s in arr.addressable_shards:
        w = s.index[0].start or 0
        blocks.setdefault(w, np.asarray(s.data))
    return [blocks[k] for k in sorted(blocks)]


rec = {"n_devices": jax.device_count()}
for het in (True, False):
    corpus = MarkovCorpus(64, seed=1)
    batches = dsm_batches(corpus, W, 2, 1, 2, 32, seed=5, heterogeneous=het)
    cross_worker_equal, cross_step_equal = 0, 0
    prev = None
    for _ in range(STEPS):
        blocks = worker_blocks(next(batches)["tokens"])
        assert len(blocks) == W
        cross_worker_equal += sum(
            np.array_equal(blocks[i], blocks[j])
            for i in range(W) for j in range(i + 1, W))
        if prev is not None:
            cross_step_equal += sum(
                np.array_equal(a, b) for a, b in zip(prev, blocks))
        prev = blocks
    rec["het" if het else "iid"] = {
        "cross_worker_equal": cross_worker_equal,
        "cross_step_equal": cross_step_equal,
    }
print("RESULT " + json.dumps(rec))
"""


@pytest.mark.multidevice
def test_heterogeneous_batches_shard_distinct_streams_8dev():
    """Under the P("worker") layout each worker's device shard carries its
    OWN stream (the paper's D_i) and advances across outer steps; with
    heterogeneous=False all workers see one replicated stream."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _HET_SCRIPT],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rec = json.loads(line[len("RESULT "):])
    assert rec["n_devices"] == 8
    # heterogeneous: no two workers ever agree, and no worker repeats a step
    assert rec["het"]["cross_worker_equal"] == 0, rec
    assert rec["het"]["cross_step_equal"] == 0, rec
    # iid split: every worker's shard is the same replicated stream
    assert rec["iid"]["cross_worker_equal"] == 3 * 6, rec
