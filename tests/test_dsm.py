"""Unit tests for Algorithm 1 (DSM): exact instance reductions and the
momentum-buffer properties the paper states."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DSMConfig,
    constant,
    dsm_init,
    make_dsm_step,
    sgd,
    signsgd_momentum_config,
)
from repro.core.dsm import global_sign_momentum_step


def quad_loss(center):
    def loss(params, batch):
        tgt = center + batch["noise"]
        return 0.5 * jnp.mean(jnp.sum((params["x"][None] - tgt) ** 2, axis=-1))

    return loss


def make_batch(key, W, tau, B, d, accum=1):
    return {"noise": 0.1 * jax.random.normal(key, (W, tau, accum, B, d))}


def test_tau1_equals_signsgd_momentum():
    """tau=1, beta1=beta2=beta, lam=0 must reproduce eq. (3) exactly."""
    d, beta, gamma, eta = 16, 0.9, 0.05, 1.0
    key = jax.random.PRNGKey(1)
    center = jax.random.normal(key, (d,))
    loss = quad_loss(center)

    cfg = signsgd_momentum_config(beta)
    step = make_dsm_step(loss, sgd(), cfg, constant(gamma))
    state = dsm_init({"x": jnp.zeros((d,))}, sgd(), n_workers=1)

    # manual eq. (3) with the same sequence of gradients
    x_manual = jnp.zeros((d,))
    m_manual = jnp.zeros((d,))
    for t in range(5):
        key, sub = jax.random.split(key)
        batch = make_batch(sub, 1, 1, 4, d)
        g = jax.grad(loss)({"x": x_manual}, jax.tree.map(lambda a: a[0, 0, 0], batch))["x"]
        m_manual = beta * m_manual + (1 - beta) * g
        x_manual = x_manual - eta * gamma * jnp.sign(m_manual)
        state, _ = step(state, batch)
        np.testing.assert_allclose(
            np.asarray(state.x0["x"]), np.asarray(x_manual), rtol=1e-5, atol=1e-6
        )


def test_momentum_lr_independent():
    """Paper: Delta is scaled by 1/gamma_t so m is LR-schedule independent."""
    d = 8
    key = jax.random.PRNGKey(2)
    center = jax.random.normal(key, (d,))
    loss = quad_loss(center)
    batch = make_batch(key, 2, 3, 4, d)

    def run(gamma):
        cfg = DSMConfig(tau=3, global_lr=0.0, weight_decay=0.0)  # eta=0: x frozen
        step = make_dsm_step(loss, sgd(), cfg, constant(gamma))
        state = dsm_init({"x": jnp.zeros((d,))}, sgd(), n_workers=2)
        state, _ = step(state, batch)
        return state.m["x"]

    m_small, m_large = run(1e-4), run(1e-3)
    # local iterates themselves depend on gamma, so allow the O(gamma)
    # second-order difference; first-order gamma-dependence must cancel
    np.testing.assert_allclose(
        np.asarray(m_small), np.asarray(m_large), rtol=5e-2, atol=1e-4
    )


def test_global_step_matches_lion_form():
    """eqs. (6)-(8) leafwise against a hand-rolled computation."""
    key = jax.random.PRNGKey(3)
    x0 = {"w": jax.random.normal(key, (5, 7))}
    m = {"w": jax.random.normal(jax.random.fold_in(key, 1), (5, 7))}
    xt = {"w": x0["w"] - 0.02 * jax.random.normal(jax.random.fold_in(key, 2), (5, 7))}
    gamma = jnp.float32(0.01)
    cfg = DSMConfig(tau=4, global_lr=0.7, beta1=0.95, beta2=0.98, weight_decay=0.1)

    new_x, new_m = global_sign_momentum_step(x0, m, xt, gamma, cfg)
    delta = (x0["w"] - xt["w"]) / gamma
    u = 0.95 * m["w"] + 0.05 * delta
    want_x = x0["w"] - 0.7 * gamma * (jnp.sign(u) + 0.1 * x0["w"])
    want_m = 0.98 * m["w"] + 0.02 * delta
    np.testing.assert_allclose(np.asarray(new_x["w"]), np.asarray(want_x), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_m["w"]), np.asarray(want_m), rtol=1e-5)


def test_kernel_path_matches_jnp_path():
    """use_kernel=True (Pallas, interpret on CPU) == jnp reference path."""
    key = jax.random.PRNGKey(4)
    x0 = {"a": jax.random.normal(key, (300,)), "b": jax.random.normal(key, (17, 9))}
    m = jax.tree.map(lambda x: jnp.zeros_like(x), x0)
    xt = jax.tree.map(lambda x: x - 0.01, x0)
    gamma = jnp.float32(0.05)
    cfg_ref = DSMConfig(tau=2)
    cfg_ker = DSMConfig(tau=2, use_kernel=True)
    xr, mr = global_sign_momentum_step(x0, m, xt, gamma, cfg_ref)
    xk, mk = global_sign_momentum_step(x0, m, xt, gamma, cfg_ker)
    for a, b in zip(jax.tree.leaves(xr), jax.tree.leaves(xk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(mr), jax.tree.leaves(mk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_kernel_with_randomized_sign_falls_back_to_jnp():
    """Regression: use_kernel=True used to silently apply the deterministic
    sign for rand_pm / rand_zero.  The kernel only implements sign; the
    randomized modes must take the jnp path and match it exactly."""
    key = jax.random.PRNGKey(8)
    x0 = {"w": jax.random.normal(key, (256,))}
    m = {"w": 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (256,))}
    xt = {"w": x0["w"] - 0.03 * jax.random.normal(jax.random.fold_in(key, 2), (256,))}
    gamma = jnp.float32(0.01)
    rng = jax.random.PRNGKey(99)
    for mode in ("rand_pm", "rand_zero"):
        cfg_jnp = DSMConfig(tau=2, sign_mode=mode, sign_bound=8.0)
        cfg_ker = DSMConfig(tau=2, sign_mode=mode, sign_bound=8.0, use_kernel=True)
        xr, mr = global_sign_momentum_step(x0, m, xt, gamma, cfg_jnp, rng)
        xk, mk = global_sign_momentum_step(x0, m, xt, gamma, cfg_ker, rng)
        np.testing.assert_array_equal(np.asarray(xr["w"]), np.asarray(xk["w"]))
        np.testing.assert_array_equal(np.asarray(mr["w"]), np.asarray(mk["w"]))
        # and the randomized sign really was applied: moves differ from the
        # deterministic-sign kernel update somewhere
        xd, _ = global_sign_momentum_step(
            x0, m, xt, gamma, DSMConfig(tau=2, use_kernel=True))
        assert np.any(np.asarray(xk["w"]) != np.asarray(xd["w"])), mode


def test_sign_update_magnitude():
    """Every coordinate moves by exactly eta*gamma (+wd term): sign in {-1,0,1}."""
    key = jax.random.PRNGKey(5)
    x0 = {"w": jax.random.normal(key, (64,))}
    m = {"w": jax.random.normal(jax.random.fold_in(key, 1), (64,))}
    xt = {"w": x0["w"] - 0.05 * jax.random.normal(jax.random.fold_in(key, 2), (64,))}
    gamma, eta = jnp.float32(0.01), 2.0
    cfg = DSMConfig(tau=1, global_lr=eta, weight_decay=0.0)
    new_x, _ = global_sign_momentum_step(x0, m, xt, gamma, cfg)
    moves = np.abs(np.asarray(new_x["w"] - x0["w"]))
    assert np.all((np.isclose(moves, eta * 0.01, atol=1e-6)) | (moves < 1e-7))


def test_worker_sync_after_outer_step():
    """Line 11: all workers hold identical params after the global step."""
    d = 8
    key = jax.random.PRNGKey(6)
    loss = quad_loss(jax.random.normal(key, (d,)))
    cfg = DSMConfig(tau=2, global_lr=0.5)
    step = make_dsm_step(loss, sgd(), cfg, constant(0.05))
    state = dsm_init({"x": jnp.zeros((d,))}, sgd(), n_workers=4)
    state, _ = step(state, make_batch(key, 4, 2, 4, d))
    p = np.asarray(state.params["x"])
    assert np.all(p == p[0:1])  # exact replica
    np.testing.assert_array_equal(p[0], np.asarray(state.x0["x"]))


def test_config_validation():
    with pytest.raises(ValueError):
        DSMConfig(sign_mode="bogus")
    with pytest.raises(ValueError):
        DSMConfig(tau=0)
    with pytest.raises(ValueError):
        DSMConfig(beta1=1.5)
