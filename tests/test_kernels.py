"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(7,), (128,), (129,), (1000,), (33, 77), (4, 128, 130), (2, 3, 5, 64)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dsm_update_kernel(shape, dtype):
    key = jax.random.PRNGKey(hash((shape, str(dtype))) % 2**31)
    ks = jax.random.split(key, 3)
    x0 = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    m = jax.random.normal(ks[1], shape, jnp.float32)
    xt = (x0.astype(jnp.float32) - 0.01 * jax.random.normal(ks[2], shape)).astype(dtype)
    gamma = jnp.float32(0.02)
    hp = dict(eta=0.8, beta1=0.95, beta2=0.98, lam=0.1)
    xr, mr = ref.dsm_update_ref(x0, m, xt, gamma, **hp)
    xk, mk = ops.dsm_update_tree({"a": x0}, {"a": m}, {"a": xt}, gamma, **hp)
    np.testing.assert_allclose(
        np.asarray(xk["a"], np.float32), np.asarray(xr, np.float32),
        rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_allclose(np.asarray(mk["a"]), np.asarray(mr), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_adamw_update_kernel(shape, dtype):
    key = jax.random.PRNGKey(hash(("adamw", shape, str(dtype))) % 2**31)
    ks = jax.random.split(key, 4)
    p = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    g = jax.random.normal(ks[1], shape, jnp.float32).astype(dtype)
    m = jax.random.normal(ks[2], shape, jnp.float32)
    v = jnp.abs(jax.random.normal(ks[3], shape, jnp.float32))
    gamma, step = jnp.float32(1e-3), jnp.float32(11)
    pr, mr, vr = ref.adamw_update_ref(
        p, g, m, v, gamma, step, beta1=0.9, beta2=0.95, eps=1e-8, wd=0.1)
    pk, mk, vk = ops.adamw_update_tree(
        {"a": p}, {"a": g}, {"a": m}, {"a": v}, gamma, step)
    np.testing.assert_allclose(
        np.asarray(pk["a"], np.float32), np.asarray(pr, np.float32),
        rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_allclose(np.asarray(mk["a"]), np.asarray(mr), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(vk["a"]), np.asarray(vr), rtol=1e-5, atol=1e-6)


def test_kernel_inside_jit_grad_free_path():
    """The kernel path composes under jit with a full pytree."""
    key = jax.random.PRNGKey(0)
    tree = {
        "layer": {"w": jax.random.normal(key, (64, 48)), "b": jnp.zeros((48,))},
        "emb": jax.random.normal(key, (100, 16)).astype(jnp.bfloat16),
    }
    m = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
    xt = jax.tree.map(lambda x: x - jnp.asarray(0.01, x.dtype), tree)

    @jax.jit
    def f(x0, m, xt):
        return ops.dsm_update_tree(
            x0, m, xt, jnp.float32(0.01), eta=1.0, beta1=0.9, beta2=0.99, lam=0.0)

    new_x, new_m = f(tree, m, xt)
    for leaf in jax.tree.leaves(new_x):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
