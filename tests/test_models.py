"""Model correctness: decode==forward consistency, MoE routing, masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models import layers as L


def _cfg(**kw):
    base = dict(
        name="t", family="lm", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=500, head_dim=16,
        dtype="float32", param_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


CASES = {
    "dense": _cfg(),
    "swa_mix": _cfg(n_layers=4, n_kv_heads=1,
                    pattern=("swa:dense",) * 3 + ("attn:dense",), window=8),
    "moe": _cfg(n_layers=2, n_kv_heads=4, d_ff=32,
                pattern=("attn:dense", "attn:moe"), n_experts=4, top_k=2),
    "ssm": _cfg(pattern=("ssm:none",), d_ff=0, ssm_state=16, ssm_head_dim=16),
    "rglru": _cfg(n_kv_heads=1,
                  pattern=("rglru:dense", "rglru:dense", "swa:dense"), window=8),
}


@pytest.mark.parametrize("name", sorted(CASES))
def test_decode_matches_forward(name):
    cfg = CASES[name]
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h, _, _ = T.hidden_states(params, {"tokens": tokens}, cfg, remat=False)
    full_logits = T._logits(params, h, cfg)
    cache = T.init_cache(cfg, B, S, jnp.float32)
    dec = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))
    errs = []
    for i in range(S):
        lg, cache = dec(params, cache, tokens[:, i], jnp.int32(i))
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, i]))))
    assert max(errs) < 2e-3, (name, max(errs))


def test_causality():
    """Changing future tokens must not change past logits."""
    cfg = CASES["dense"]
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab_size)
    t2 = t1.at[0, 10:].set((t1[0, 10:] + 7) % cfg.vocab_size)
    h1, _, _ = T.hidden_states(params, {"tokens": t1}, cfg, remat=False)
    h2, _, _ = T.hidden_states(params, {"tokens": t2}, cfg, remat=False)
    np.testing.assert_allclose(
        np.asarray(h1[:, :10]), np.asarray(h2[:, :10]), atol=1e-5
    )
    assert float(jnp.abs(h1[:, 10:] - h2[:, 10:]).max()) > 1e-4


def test_sliding_window_locality():
    """With window w, logits at position t depend only on tokens > t-w."""
    cfg = _cfg(n_layers=2, n_kv_heads=1, pattern=("swa:dense",), window=4)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 20), 0, cfg.vocab_size)
    # perturb a token far outside every live window of the last position
    t2 = t1.at[0, 2].set((t1[0, 2] + 3) % cfg.vocab_size)
    h1, _, _ = T.hidden_states(params, {"tokens": t1}, cfg, remat=False)
    h2, _, _ = T.hidden_states(params, {"tokens": t2}, cfg, remat=False)
    # receptive field of 2 stacked window-4 layers ~ 8; position 19 unaffected
    np.testing.assert_allclose(
        np.asarray(h1[:, -1]), np.asarray(h2[:, -1]), atol=1e-5
    )


def test_moe_gates_and_flops_path():
    """MoE: output is a convex combination of <=top_k experts + shared."""
    cfg = CASES["moe"]
    key = jax.random.PRNGKey(2)
    p = L.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model))
    out, aux = L.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound is 1 at uniform


def test_moe_matches_dense_gather_oracle():
    """ragged_dot grouped matmul == per-token gather-and-matmul oracle."""
    cfg = _cfg(n_layers=1, d_ff=16, pattern=("attn:moe",), n_experts=4, top_k=2)
    key = jax.random.PRNGKey(3)
    p = L.init_moe(key, cfg)
    x = jax.random.normal(key, (1, 6, cfg.d_model))
    out, _ = L.moe_apply(p, x, cfg)

    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    want = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(2):
            e = int(idx[t, j])
            h = jax.nn.silu(xt[t] @ p["we1"][e]) * (xt[t] @ p["we3"][e])
            acc = acc + gates[t, j] * (h @ p["we2"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(
        np.asarray(out.reshape(-1, cfg.d_model)), np.asarray(want),
        rtol=2e-4, atol=2e-4,
    )


def test_loss_mask_excludes_final_position():
    cfg = CASES["dense"]
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    l1 = T.loss_fn(params, {"tokens": tokens}, cfg, remat=False)
    # changing ONLY the content that position 15 predicts (nothing) is a no-op:
    # i.e., loss is identical for any value of a hypothetical position 16.
    assert np.isfinite(float(l1))


def test_vlm_prefix_excluded_from_loss():
    cfg = _cfg(family="vlm", n_layers=2, n_patches=4)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    patches = jax.random.normal(jax.random.PRNGKey(2), (2, 4, cfg.d_model))
    loss = T.loss_fn(params, {"tokens": tokens, "patches": patches}, cfg, remat=False)
    assert np.isfinite(float(loss))


def test_remat_equals_no_remat():
    cfg = CASES["dense"]
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    l1 = T.loss_fn(params, {"tokens": tokens}, cfg, remat=False)
    l2 = T.loss_fn(params, {"tokens": tokens}, cfg, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    g1 = jax.grad(lambda p: T.loss_fn(p, {"tokens": tokens}, cfg, remat=False))(params)
    g2 = jax.grad(lambda p: T.loss_fn(p, {"tokens": tokens}, cfg, remat=True))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
