"""Observability subsystem tests (docs/observability.md).

Covers the on-device metric pack (jit/eager parity, budget-free sanity),
the run sinks (JSONL/CSV round-trip, resume append, truncated-tail
tolerance), the summarize CLI against a REAL instrumented smoke run,
guard-counter persistence across --resume, the perf snapshot, and — in the
8-forced-device subprocess tier — sharded-vs-dense pack parity plus a
non-degenerate comm ledger (observed collective bytes with ratios).

The sanitizer-backed test is the load-bearing one: an instrumented run
under ``sanitize=True`` proves the pack adds no host transfers inside the
hot loop and the steady-state outer step still compiles exactly once.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import DSMConfig, constant, dsm_init, make_dsm_step, sgd
from repro.obs import metrics as OM
from repro.obs import sinks as OS
from repro.obs import tracing as OT
from repro.obs.summarize import diff as summarize_diff
from repro.obs.summarize import render, summarize_run

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))

NANO = ModelConfig(
    name="nano", family="lm", n_layers=1, d_model=32, n_heads=2,
    n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16, mlp_gated=False,
    act="gelu", dtype="float32", param_dtype="float32", vocab_pad_to=64,
)


def _env_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (os.path.abspath(SRC) + os.pathsep + ROOT
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return env


# ---------------------------------------------------------------------------
# metric pack: jit/eager parity and sanity of the formulas
# ---------------------------------------------------------------------------

def _tiny_dsm_step_and_state():
    d = 32
    center = jax.random.normal(jax.random.PRNGKey(0), (d,))

    def loss(params, mb):
        return 0.5 * jnp.mean(jnp.sum((params["x"][None] - center - mb) ** 2,
                                      axis=-1))

    cfg = DSMConfig(tau=2, global_lr=0.5)
    step = make_dsm_step(loss, sgd(), cfg, constant(0.05))
    state = dsm_init({"x": jnp.zeros((d,))}, sgd(), n_workers=2)
    batch = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 2, 1, 4, d))
    return step, state, batch


def test_pack_jit_eager_parity():
    """The pack is pure jnp: jit and eager produce identical values."""
    step, state, batch = _tiny_dsm_step_and_state()
    jstep = jax.jit(step)
    # two rounds so the momentum is non-zero and sign_agree is meaningful
    for _ in range(2):
        state, mj = jstep(state, batch)
    with jax.disable_jit():
        _, st, _ = _tiny_dsm_step_and_state()
        for _ in range(2):
            st, me = step(st, batch)
    pj = np.asarray(mj["pack"], np.float64)
    pe = np.asarray(me["pack"], np.float64)
    assert pj.shape == (OM.N_METRICS,)
    # XLA fusion reassociates the f32 sums; parity is to float tolerance
    # (worker_spread = sqrt(E[x^2] - E[x]^2) cancels ~6 digits, so its
    # error floor scales with the loss, hence the absolute term)
    np.testing.assert_allclose(pj, pe, rtol=5e-4, atol=5e-4)


def test_pack_values_sane():
    step, state, batch = _tiny_dsm_step_and_state()
    jstep = jax.jit(step)
    state, m = jstep(state, batch)          # round 1: m starts at zero
    p1 = OS.pack_to_dict(jax.device_get(m["pack"]))
    assert p1["sign_agree"] == 0.0          # sign(0) * sign(delta) is never > 0
    assert p1["m_l1"] == 0.0
    state, m = jstep(state, batch)          # round 2: momentum is live
    p2 = OS.pack_to_dict(jax.device_get(m["pack"]))
    assert 0.0 < p2["pg_density"] <= 1.0
    assert 0.0 <= p2["sign_agree"] <= 1.0
    assert p2["m_l1"] > 0.0
    assert -1.0 <= p2["update_cos"] <= 1.0
    assert p2["survivor_frac"] == 1.0       # dense round
    assert p2["guard_ok"] == 1.0            # no guard wrapper -> default
    assert p2["worker_spread"] >= 0.0
    assert np.isclose(p2["loss"], float(m["loss"]))
    # ||.||_1 >= ||.||_2 always; equality only for one-hot vectors
    assert p2["pg_l1"] >= p2["pg_l2"] > 0.0


def test_guard_verdict_lands_in_pack():
    """A rejected round gets guard_ok=0 in its pack (device-side select)."""
    from repro.robustness.guards import init_guard, make_guarded_step

    def fake_step(state, loss_val):
        pack = OM.minimal_pack(loss_val)
        return state + 1.0, {"loss": loss_val, "pack": pack}

    guarded = jax.jit(make_guarded_step(fake_step, nonfinite=True))
    state, guard = jnp.zeros(()), init_guard()
    state, guard, m = guarded(state, guard, jnp.float32(1.0))
    assert OS.pack_to_dict(jax.device_get(m["pack"]))["guard_ok"] == 1.0
    state, guard, m = guarded(state, guard, jnp.float32(jnp.nan))
    assert OS.pack_to_dict(jax.device_get(m["pack"]))["guard_ok"] == 0.0
    assert float(state) == 1.0              # rejected round kept the state


def test_pack_to_dict_rejects_wrong_length():
    with pytest.raises(ValueError, match="entries"):
        OS.pack_to_dict(np.zeros(OM.N_METRICS - 1))


# ---------------------------------------------------------------------------
# sinks: JSONL/CSV round-trip, resume append, truncated-tail tolerance
# ---------------------------------------------------------------------------

def test_runwriter_roundtrip_and_resume(tmp_path):
    run_dir = str(tmp_path / "run")
    manifest = OS.build_manifest(run_name="run", extra={"note": "t"})
    with OS.RunWriter(run_dir, manifest) as w:
        w.event("started", steps=3)
        w.metrics_row(1, np.arange(OM.N_METRICS, dtype=np.float64))
        w.span("eval", 0.25, step=1)
    man, events, rows = OS.read_run(run_dir)
    assert man["run_name"] == "run"
    assert man["metric_names"] == list(OM.METRIC_NAMES)
    assert [e["kind"] for e in events] == ["started", "span"]
    assert all("wall" in e for e in events)
    assert rows[0]["step"] == 1 and rows[0]["loss"] == 0.0
    assert rows[0]["guard_ok"] == float(OM.IDX["guard_ok"])

    # resume append: history is kept, the header is not rewritten
    with OS.RunWriter(run_dir, manifest, resume=True) as w:
        w.event("resumed", step=1)
        w.metrics_row(2, np.arange(OM.N_METRICS, dtype=np.float64) + 1)
    _, events, rows = OS.read_run(run_dir)
    assert [e["kind"] for e in events] == ["started", "span", "resumed"]
    assert [r["step"] for r in rows] == [1, 2]
    with open(os.path.join(run_dir, "scalars.csv")) as f:
        assert sum(line.startswith("step,") for line in f) == 1

    # a killed run leaves torn tails; readers must survive both
    with open(os.path.join(run_dir, "events.jsonl"), "a") as f:
        f.write('{"kind": "trunc')
    with open(os.path.join(run_dir, "scalars.csv"), "a") as f:
        f.write("3,0.5,0.1")  # partial row
    _, events, rows = OS.read_run(run_dir)
    assert [e["kind"] for e in events] == ["started", "span", "resumed"]
    assert [r["step"] for r in rows] == [1, 2]


def test_tracing_primitives():
    assert OT.parse_profile_steps(None) is None
    assert OT.parse_profile_steps("3:7") == (3, 7)
    with pytest.raises(ValueError):
        OT.parse_profile_steps("7:3")
    with pytest.raises(ValueError):
        OT.parse_profile_steps("x")

    x = jnp.ones((4,))
    with OT.Span("s", x) as sp:
        sp.add_fence(x * 2)
    assert sp.seconds >= 0.0

    tot = OT.PhaseTotals()
    tot.add("train_window", 1.0, n=4)
    tot.add("train_window", 1.0, n=4)
    d = tot.as_dict()
    assert d["train_window"]["seconds"] == 2.0
    assert d["train_window"]["ms_per"] == 250.0


# ---------------------------------------------------------------------------
# comm model: the analytic side of the ledger
# ---------------------------------------------------------------------------

def test_wire_bytes_model_matches_outer_step_report():
    from benchmarks.comm import bytes_per_outer_step, wire_bytes_for_payload

    payload = 1 << 20
    assert wire_bytes_for_payload(payload, "dsm", tau=12) == (2 * payload, 1)
    assert wire_bytes_for_payload(payload, "perstep", tau=12) == (
        2 * payload * 12, 12)
    sign_wire, sign_rounds = wire_bytes_for_payload(payload, "mv_signsgd",
                                                    tau=12, param_bytes=2)
    assert sign_wire == payload // 16 * 2 and sign_rounds == 1
    with pytest.raises(ValueError):
        wire_bytes_for_payload(payload, "nope", tau=12)

    # the per-arch report is built on the same helper: tau x reduction
    dsm = bytes_per_outer_step("gpt2_small", "dsm", tau=12)
    ps = bytes_per_outer_step("gpt2_small", "perstep", tau=12)
    assert ps["wire_bytes_per_outer"] == 12 * dsm["wire_bytes_per_outer"]
    assert (dsm["comm_rounds_per_outer"], ps["comm_rounds_per_outer"]) == (1, 12)


# ---------------------------------------------------------------------------
# the real thing: an instrumented smoke run through the trainer
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_run(tmp_path_factory):
    """ONE short instrumented DSM training shared by the assertions below."""
    from repro.data.pipeline import MarkovCorpus
    from repro.train.trainer import TrainSettings, run_training

    run_dir = str(tmp_path_factory.mktemp("obs") / "smoke")
    s = TrainSettings(algorithm="dsm", n_workers=2, tau=2, steps=4,
                      b_micro=2, seq=32, eval_every=2, log_every=1,
                      run_dir=run_dir)
    logs = []
    result = run_training(NANO, s, MarkovCorpus(NANO.vocab_size, seed=7),
                          log=logs.append)
    return run_dir, result, logs, s


def test_smoke_run_dir_contents(smoke_run):
    run_dir, result, logs, s = smoke_run
    man, events, rows = OS.read_run(run_dir)
    assert man["settings"]["algorithm"] == "dsm"
    assert man["metric_names"] == list(OM.METRIC_NAMES)
    # outer-step numbering is consistent: one row per round, 1..steps
    assert [r["step"] for r in rows] == list(range(1, s.steps + 1))
    for r in rows:
        assert np.isfinite(r["loss"]) and np.isfinite(r["pg_l1"])
        assert 0.0 <= r["sign_agree"] <= 1.0
        assert r["survivor_frac"] == 1.0 and r["guard_ok"] == 1.0
    # the logged train losses come from the SAME rows (satellite: the log
    # line and scalars.csv can never disagree about a step again)
    by_step = {r["step"]: r for r in rows}
    for line in logs:
        if line.startswith("step"):
            parts = line.split()
            step, train = int(parts[1]), float(parts[2].split("=")[1])
            assert np.isclose(train, by_step[step]["loss"], atol=5e-5), line
    kinds = [e["kind"] for e in events]
    assert kinds.count("comm_ledger") == 1
    assert "finished" in kinds and "eval" in kinds
    ledger = next(e for e in events if e["kind"] == "comm_ledger")
    assert ledger["predicted"]["wire_bytes_per_outer"] > 0
    assert ledger["predicted"]["payload_bytes"] > 0
    assert ledger["degenerate_mesh"]  # 1-device host: ratios suppressed
    assert ledger["ratio"]["reduce"] is None
    span_names = {e["name"] for e in events if e["kind"] == "span"}
    assert {"train_window", "eval", "local_phase", "global_step"} <= span_names
    fin = next(e for e in events if e["kind"] == "finished")
    assert fin["steps"] == s.steps and fin["tokens"] == result["tokens"]
    assert result["phase_ms"] is not None
    assert result["final_metrics"]["loss"] == rows[-1]["loss"]
    assert result["run_dir"] == run_dir


def test_summarize_api_and_render(smoke_run):
    run_dir, _, _, s = smoke_run
    summary = summarize_run(run_dir)
    assert summary["steps_logged"] == s.steps
    assert summary["scalars"]["sign_agree"]["last"] is not None
    assert summary["comm_ledger"]["predicted"]["wire_bytes_per_outer"] > 0
    text = render(summary)
    assert "sign_agree" in text
    assert "wire" in text or "bytes" in text
    # diff against itself must not crash and mentions both runs
    assert "smoke" in summarize_diff(summary, summary)


def test_summarize_cli_on_real_run(smoke_run):
    run_dir, _, _, _ = smoke_run
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs", "summarize", run_dir],
        capture_output=True, text=True, timeout=120, env=_env_8dev(),
        cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "sign_agree" in proc.stdout
    assert "comm" in proc.stdout.lower()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs", "summarize", run_dir, "--json"],
        capture_output=True, text=True, timeout=120, env=_env_8dev(),
        cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert json.loads(proc.stdout)["steps_logged"] == 4
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs", "summarize", run_dir + "_nope"],
        capture_output=True, text=True, timeout=120, env=_env_8dev(),
        cwd=ROOT)
    assert proc.returncode == 2


def test_summarize_dedupes_rollback_duplicate_steps(tmp_path):
    """Rollback/resume re-log step numbers; summarize keeps the LAST row."""
    run_dir = str(tmp_path / "dup")
    with OS.RunWriter(run_dir, OS.build_manifest(run_name="dup")) as w:
        row = np.zeros(OM.N_METRICS)
        for step, loss in ((1, 5.0), (2, 9.0), (2, 4.0)):
            row[OM.IDX["loss"]] = loss
            w.metrics_row(step, row)
        w.event("finished", steps=2, wall_s=1.0, steps_per_s=2.0,
                tokens=10, tokens_per_s=10.0)
    summary = summarize_run(run_dir)
    assert summary["steps_logged"] == 2
    assert summary["scalars"]["loss"]["last"] == 4.0
    assert summary["scalars"]["loss"]["max"] == 5.0  # 9.0 was rolled back


def test_instrumented_run_passes_sanitizers(tmp_path):
    """Sanitizer-backed budget proof: with the pack + async flushes the hot
    loop still makes NO implicit host transfers and the outer step compiles
    exactly once (a second compile or a blocking read raises)."""
    from repro.data.pipeline import MarkovCorpus
    from repro.train.trainer import TrainSettings, run_training

    s = TrainSettings(algorithm="dsm", n_workers=2, tau=2, steps=4,
                      b_micro=2, seq=32, eval_every=2,
                      run_dir=str(tmp_path / "san"), sanitize=True)
    r = run_training(NANO, s, MarkovCorpus(NANO.vocab_size, seed=7))
    assert r["step_compiles"] == 1
    assert np.isfinite(r["final_eval"])


def test_baseline_rows_have_nan_dsm_slots(tmp_path):
    """Baselines log loss/gamma rows; DSM-only metrics stay NaN, so the CSV
    schema is ONE table for every algorithm."""
    from repro.data.pipeline import MarkovCorpus
    from repro.train.trainer import TrainSettings, run_training

    s = TrainSettings(algorithm="slowmo", n_workers=2, tau=2, steps=2,
                      b_micro=2, seq=32, eval_every=2,
                      run_dir=str(tmp_path / "bl"))
    run_training(NANO, s, MarkovCorpus(NANO.vocab_size, seed=7))
    _, _, rows = OS.read_run(s.run_dir)
    assert [r["step"] for r in rows] == [1, 2]
    for r in rows:
        assert np.isfinite(r["loss"])
        assert np.isnan(r["pg_l1"]) and np.isnan(r["sign_agree"])


def test_guard_counters_survive_resume(tmp_path):
    """Cumulative skipped_rounds persist in the checkpoint extra: a resumed
    run reports totals since step 0, not since the restart."""
    from repro.checkpoint import checkpoint as CK
    from repro.data.pipeline import MarkovCorpus
    from repro.train.trainer import TrainSettings, run_training

    ck = str(tmp_path / "ck")
    # spike_factor ~0: round 1 seeds the EMA, every later round is rejected
    common = dict(algorithm="dsm", n_workers=2, tau=2, b_micro=2, seq=32,
                  eval_every=2, guard_spike_factor=1e-6, guard_patience=100,
                  checkpoint_dir=ck, checkpoint_every=2)
    corpus = MarkovCorpus(NANO.vocab_size, seed=7)
    r1 = run_training(NANO, TrainSettings(steps=4, **common), corpus)
    assert r1["skipped_rounds"] == 3
    extra = CK.load_meta(CK.latest_checkpoint(ck)).get("extra")
    assert extra["skipped_rounds"] == 3 and extra["rollbacks"] == 0

    r2 = run_training(NANO, TrainSettings(steps=8, resume=True, **common),
                      corpus)
    assert r2["skipped_rounds"] == 7  # 3 from before the restart + 4 new
    extra = CK.load_meta(CK.latest_checkpoint(ck)).get("extra")
    assert extra["skipped_rounds"] == 7


def test_perf_snapshot_smoke(tmp_path):
    from benchmarks.perf import perf_snapshot, write_snapshot

    snap = perf_snapshot(steps=2, n_workers=2, tau=2,
                         run_dir=str(tmp_path / "perf"))
    assert snap["steps_per_s"] > 0 and snap["tokens_per_s"] > 0
    assert "local_phase" in snap["phase_ms"]
    path = write_snapshot(snap, out_dir=str(tmp_path))
    assert os.path.basename(path) == "BENCH_nano_dsm.json"
    with open(path) as f:
        assert json.load(f)["steps"] == 2


# ---------------------------------------------------------------------------
# 8 devices: sharded pack parity + a non-degenerate comm ledger
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = r"""
import json, os, sys
import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import MarkovCorpus
from repro.obs import sinks as OS
from repro.obs.summarize import summarize_run
from repro.train.trainer import TrainSettings, run_training

NANO = ModelConfig(
    name="nano", family="lm", n_layers=1, d_model=32, n_heads=2,
    n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16, mlp_gated=False,
    act="gelu", dtype="float32", param_dtype="float32", vocab_pad_to=64,
)
out = sys.argv[1]
rec = {"n_devices": jax.device_count(), "rows": {}}

for tag, kw in (
    ("dense", {}),
    ("sharded", {"zero_sharded": True, "device_parallel_local": True}),
):
    s = TrainSettings(algorithm="dsm", n_workers=4, tau=2, steps=4,
                      b_micro=2, seq=32, eval_every=4,
                      run_dir=os.path.join(out, tag), **kw)
    run_training(NANO, s, MarkovCorpus(NANO.vocab_size, seed=7))
    _, events, rows = OS.read_run(s.run_dir)
    rec["rows"][tag] = rows
    if tag == "sharded":
        rec["ledger"] = next(e for e in events if e["kind"] == "comm_ledger")
        rec["spans"] = sorted({e["name"] for e in events
                               if e["kind"] == "span"})
        rec["summary"] = summarize_run(s.run_dir)

print("RESULT " + json.dumps(rec))
"""


@pytest.mark.multidevice
def test_sharded_pack_and_ledger_8dev(tmp_path):
    """On a forced 8-device host (worker=4, zero=2): the ZeRO-sharded
    instrumented run logs the same pack values as the dense run (the single
    stacked psum reconstructs the replicated sums), and the comm ledger is
    non-degenerate — observed all-reduce bytes > 0 with an observed/
    predicted ratio."""
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT, str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=_env_8dev(),
        cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rec = json.loads(line[len("RESULT "):])
    assert rec["n_devices"] == 8

    dense, sharded = rec["rows"]["dense"], rec["rows"]["sharded"]
    assert [r["step"] for r in dense] == [r["step"] for r in sharded]
    for rd, rs in zip(dense, sharded):
        for name in ("loss", "pg_l1", "pg_l2", "pg_density", "sign_agree",
                     "m_l1", "update_cos", "worker_spread"):
            a, b = rd[name], rs[name]
            # absolute term: worker_spread's sqrt(E[x^2]-E[x]^2) form
            # cancels, leaving loss-scale float error
            assert abs(a - b) <= 1e-3 + 1e-3 * abs(a), (name, rd, rs)

    ledger = rec["ledger"]
    assert not ledger["degenerate_mesh"]
    assert ledger["observed"]["reduce_bytes"] > 0
    assert ledger["observed"]["reduce_ops"] > 0
    assert ledger["ratio"]["reduce"] is not None
    assert {"train_window", "local_phase", "global_step"} <= set(rec["spans"])
    # the summary renders observed-vs-predicted comm volume from real HLO
    assert rec["summary"]["comm_ledger"]["observed"]["reduce_bytes"] > 0
