"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import DSMConfig, randomized_sign_pm, randomized_sign_zero
from repro.core.dsm import global_sign_momentum_step
from repro.models.layers import ssd_chunked

SET = settings(max_examples=20, deadline=None, derandomize=True)


# ---------------------------------------------------------------------------
# Lemma 1: randomized sign operators are unbiased: E[S_r(v)] = v / B
# ---------------------------------------------------------------------------

@SET
@given(st.integers(0, 2**31 - 1), st.floats(0.5, 4.0))
def test_randomized_sign_pm_unbiased(seed, bound_scale):
    key = jax.random.PRNGKey(seed)
    v = jax.random.uniform(key, (64,), minval=-1.0, maxval=1.0)
    bound = float(jnp.linalg.norm(v)) * bound_scale  # ||v|| <= B required
    keys = jax.random.split(jax.random.fold_in(key, 1), 4000)
    samples = jax.vmap(lambda k: randomized_sign_pm(v, k, bound))(keys)
    mean = samples.mean(axis=0)
    # se of each coordinate mean ~ 1/sqrt(4000) = 0.016; max over 64 coords
    # needs ~4 sigma of slack
    np.testing.assert_allclose(
        np.asarray(mean), np.asarray(v / bound), atol=8e-2
    )
    # variance bound: E||S_r(v) - v/B||^2 <= d
    sq = ((samples - v / bound) ** 2).sum(-1).mean()
    assert float(sq) <= v.shape[0] + 1.0


@SET
@given(st.integers(0, 2**31 - 1))
def test_randomized_sign_zero_unbiased(seed):
    key = jax.random.PRNGKey(seed)
    v = jax.random.uniform(key, (64,), minval=-1.0, maxval=1.0)
    bound = float(jnp.linalg.norm(v)) * 1.5
    keys = jax.random.split(jax.random.fold_in(key, 1), 4000)
    samples = jax.vmap(lambda k: randomized_sign_zero(v, k, bound))(keys)
    np.testing.assert_allclose(
        np.asarray(samples.mean(0)), np.asarray(v / bound), atol=8e-2
    )
    vals = np.unique(np.asarray(samples))
    assert set(vals).issubset({-1.0, 0.0, 1.0})


# ---------------------------------------------------------------------------
# Global-step invariants
# ---------------------------------------------------------------------------

@SET
@given(
    st.integers(0, 2**31 - 1),
    st.floats(0.0, 0.999),
    st.floats(0.0, 0.999),
    st.floats(1e-4, 0.1),
    st.floats(0.1, 3.0),
)
def test_global_step_finite_and_bounded(seed, b1, b2, gamma, eta):
    key = jax.random.PRNGKey(seed)
    x0 = {"w": jax.random.normal(key, (32,))}
    m = {"w": jax.random.normal(jax.random.fold_in(key, 1), (32,))}
    xt = {"w": x0["w"] - gamma * jax.random.normal(jax.random.fold_in(key, 2), (32,))}
    cfg = DSMConfig(tau=2, global_lr=eta, beta1=b1, beta2=b2, weight_decay=0.0)
    new_x, new_m = global_sign_momentum_step(x0, m, xt, jnp.float32(gamma), cfg)
    assert np.isfinite(np.asarray(new_x["w"])).all()
    assert np.isfinite(np.asarray(new_m["w"])).all()
    # sign-update step size bound: |x_new - x0| <= eta*gamma  (lam=0),
    # up to f32 rounding of (x0 - eta*gamma*s) - x0 (ulp(x0) >> ulp(step))
    tol = eta * gamma * 1e-2 + 3e-7 * float(jnp.abs(x0["w"]).max())
    assert np.all(np.abs(np.asarray(new_x["w"] - x0["w"])) <= eta * gamma + tol)
    # m_new is a convex combination: ||m_new||_inf <= max(||m||_inf, ||delta||_inf)
    delta = (x0["w"] - xt["w"]) / gamma
    bound = max(float(jnp.abs(m["w"]).max()), float(jnp.abs(delta).max()))
    assert float(jnp.abs(new_m["w"]).max()) <= bound * (1 + 1e-5) + 1e-6


# ---------------------------------------------------------------------------
# Mamba-2 SSD: chunked algorithm == naive linear recurrence
# ---------------------------------------------------------------------------

@SET
@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from([(1, 8, 2, 4, 4), (2, 16, 3, 8, 8), (1, 32, 1, 16, 4)]),
)
def test_ssd_chunked_matches_recurrence(seed, dims):
    B, S, H, P, N = dims
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = jnp.exp(jax.random.uniform(ks[2], (H,), minval=-1.0, maxval=1.0))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))

    y_chunk = ssd_chunked(x, dt, A, Bm, Cm, chunk=min(8, S))

    # naive: h_t = h_{t-1} * exp(-A dt_t) + dt_t * outer(B_t, x_t); y = C_t . h
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(-A[None, :] * dt[:, t])                     # (B,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], x[:, t])
        h = h * dA[..., None, None] + dBx
        ys.append(jnp.einsum("bhpn,bn->bhp", h, Cm[:, t]))
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_chunk), np.asarray(y_naive), rtol=2e-3, atol=2e-3
    )


# ---------------------------------------------------------------------------
# Kernel vs oracle under random shapes/dtypes
# ---------------------------------------------------------------------------

@SET
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 700),
    st.sampled_from(["float32", "bfloat16"]),
)
def test_dsm_kernel_matches_ref_property(seed, n, dtype):
    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(seed)
    dt = jnp.dtype(dtype)
    x0 = jax.random.normal(key, (n,), jnp.float32).astype(dt)
    m = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    xt = (x0.astype(jnp.float32) - 0.02).astype(dt)
    gamma = jnp.float32(0.01)
    hp = dict(eta=1.0, beta1=0.95, beta2=0.98, lam=0.1)
    xr, mr = ref.dsm_update_ref(x0, m, xt, gamma, **hp)
    xk, mk = ops.dsm_update_tree({"a": x0}, {"a": m}, {"a": xt}, gamma, **hp)
    np.testing.assert_allclose(
        np.asarray(xk["a"], np.float32), np.asarray(xr, np.float32),
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_allclose(np.asarray(mk["a"]), np.asarray(mr), rtol=1e-5, atol=1e-5)
