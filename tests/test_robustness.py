"""Chaos suite: fault injection, survivor-aware DSM, guards, kill + resume.

Acceptance criteria (ISSUE 8):
  (a) a run with 25% seeded worker dropout reaches a final eval loss within
      10% of the fault-free run;
  (b) kill-at-round-k + resume reproduces the uninterrupted run's x0
      bit-exactly at the same round;
  (c) injected NaN contributions are masked and never propagate into x0 or
      m (jnp.isfinite over the FULL state every round).

The genuine kill test (SIGKILL mid-run, then --resume) forces 8 host
devices and runs the sharded + device-parallel stack, so it lives in the
``multidevice`` tier; everything else is fast-tier.
"""

import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import (
    DSMConfig,
    constant,
    dsm_init,
    make_dsm_step,
    masked_worker_mean,
    sgd,
    worker_finite_mask,
)
from repro.data.pipeline import MarkovCorpus
from repro.robustness.faults import FaultPlan, FaultRound, FaultSpec, apply_faults
from repro.robustness.guards import init_guard, make_guarded_step
from repro.train.trainer import TrainSettings, run_training

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

NANO = ModelConfig(
    name="nano", family="lm", n_layers=1, d_model=32, n_heads=2,
    n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16, mlp_gated=False,
    act="gelu", dtype="float32", param_dtype="float32", vocab_pad_to=64,
)


def nano_settings(**kw):
    base = dict(algorithm="dsm", n_workers=4, tau=2, steps=8, b_micro=2,
                seq=32, eval_every=4)
    base.update(kw)
    return TrainSettings(**base)


# ---------------------------------------------------------------------------
# FaultPlan: deterministic, seeded, parseable
# ---------------------------------------------------------------------------

def test_fault_spec_parse():
    spec = FaultSpec.parse("drop=0.25, straggle=0.1, nan=0.05, seed=3")
    assert spec == FaultSpec(p_drop=0.25, p_straggle=0.1, p_corrupt=0.05, seed=3)
    with pytest.raises(ValueError, match="unknown fault key"):
        FaultSpec.parse("explode=1.0")
    with pytest.raises(ValueError, match="bad fault spec"):
        FaultSpec.parse("drop")
    with pytest.raises(ValueError, match="lie in"):
        FaultSpec(p_drop=1.5)


def test_fault_plan_deterministic_and_shapes():
    spec = FaultSpec(p_drop=0.3, p_straggle=0.2, p_corrupt=0.1, seed=11)
    a = FaultPlan(8, 20, spec)
    b = FaultPlan.from_spec("drop=0.3,straggle=0.2,nan=0.1,seed=11", 8, 20)
    np.testing.assert_array_equal(a.drop, b.drop)
    np.testing.assert_array_equal(a.stale, b.stale)
    np.testing.assert_array_equal(a.corrupt, b.corrupt)
    assert a.drop.shape == (20, 8)
    fr = a.round(5)
    assert fr.survivors.shape == (8,) and fr.survivors.dtype == bool
    np.testing.assert_array_equal(np.asarray(fr.survivors), ~a.drop[5])
    # horizon-independent: round t's faults do not depend on plan length
    short = FaultPlan(8, 5, spec)
    np.testing.assert_array_equal(a.drop[:5], short.drop)
    np.testing.assert_array_equal(a.corrupt[:5], short.corrupt)
    # beyond the planned horizon: fault-free
    late = a.round(99)
    assert bool(late.survivors.all()) and not bool(late.corrupt.any())
    assert 0.0 < a.dropped_frac() < 1.0


def test_apply_faults_stale_and_corrupt():
    params_w = {"x": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) + 100.0}
    x0 = {"x": jnp.arange(4, dtype=jnp.float32)}
    fr = FaultRound(
        survivors=jnp.array([True, True, True]),
        stale=jnp.array([False, True, False]),
        corrupt=jnp.array([False, False, True]),
    )
    out = apply_faults(params_w, x0, fr)["x"]
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(params_w["x"][0]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(x0["x"]))
    assert bool(jnp.isnan(out[2]).all())


# ---------------------------------------------------------------------------
# survivor-aware aggregation primitives
# ---------------------------------------------------------------------------

def test_worker_finite_mask():
    tree = {
        "a": jnp.array([[1.0, 2.0], [jnp.nan, 1.0], [3.0, 4.0]]),
        "b": jnp.array([[0.0], [1.0], [jnp.inf]]),
        "ints": jnp.zeros((3, 2), jnp.int32),  # non-float leaves are ignored
    }
    np.testing.assert_array_equal(
        np.asarray(worker_finite_mask(tree)), [True, False, False])


def test_masked_worker_mean_excludes_dropped_and_is_nan_safe():
    p = jnp.array([[2.0, 4.0], [jnp.nan, jnp.nan], [6.0, 8.0]])
    w = jnp.array([1.0, 0.0, 1.0])
    out = masked_worker_mean({"x": p}, w)["x"]
    np.testing.assert_allclose(np.asarray(out), [4.0, 6.0])
    # all-zero weights: returns zeros (the caller applies skip semantics)
    out0 = masked_worker_mean({"x": p}, jnp.zeros(3))["x"]
    assert bool(jnp.isfinite(out0).all())


# ---------------------------------------------------------------------------
# chaos (c): injected NaNs never reach x0 / m — full-state finiteness every
# round, under heavy simultaneous drop + straggle + corruption
# ---------------------------------------------------------------------------

def _quad_problem(d=24, n_workers=4):
    key = jax.random.PRNGKey(7)
    center = jax.random.normal(key, (d,))

    def loss(params, batch):
        tgt = center + batch["noise"]
        return 0.5 * jnp.mean(jnp.sum((params["x"][None] - tgt) ** 2, axis=-1))

    def batch_at(t):
        return {"noise": 0.1 * jax.random.normal(
            jax.random.fold_in(key, t), (n_workers, 2, 1, 4, d))}

    return loss, batch_at, d


@pytest.mark.parametrize("zero_sharded", [False, True])
def test_injected_nans_never_reach_state(zero_sharded):
    loss, batch_at, d = _quad_problem()
    mesh = None
    if zero_sharded:
        from repro.launch.mesh import host_training_mesh

        mesh = host_training_mesh(4)  # degenerate worker=1 mesh on 1 device
    cfg = DSMConfig(tau=2, global_lr=0.7, zero_sharded=zero_sharded)
    step = jax.jit(make_dsm_step(loss, sgd(), cfg, constant(0.05), mesh=mesh))
    state = dsm_init({"x": jnp.zeros((d,))}, sgd(), n_workers=4, mesh=mesh,
                     global_sharded=zero_sharded)
    plan = FaultPlan(4, 12, FaultSpec(p_drop=0.3, p_straggle=0.3,
                                      p_corrupt=0.5, seed=1))
    assert plan.corrupt.any()  # the injection is not vacuous
    for t in range(12):
        state, metrics = step(state, batch_at(t), None, plan.round(t))
        for leaf in jax.tree.leaves(state):
            assert bool(jnp.isfinite(leaf).all()), (t, zero_sharded)
    # ... and the run actually trained (x0 moved despite the chaos)
    assert float(jnp.abs(state.x0["x"]).max()) > 0.0


def test_all_dropped_round_is_skipped_bit_exactly():
    loss, batch_at, d = _quad_problem()
    cfg = DSMConfig(tau=2, global_lr=0.7)
    step = jax.jit(make_dsm_step(loss, sgd(), cfg, constant(0.05)))
    state = dsm_init({"x": jnp.zeros((d,))}, sgd(), n_workers=4)
    state, _ = step(state, batch_at(0), None, FaultPlan(4, 1, FaultSpec()).round(0))
    dead = FaultRound(survivors=jnp.zeros(4, bool), stale=jnp.zeros(4, bool),
                      corrupt=jnp.zeros(4, bool))
    x0_before = np.asarray(state.x0["x"]).copy()
    m_before = np.asarray(state.m["x"]).copy()
    state2, metrics = step(state, batch_at(1), None, dead)
    np.testing.assert_array_equal(np.asarray(state2.x0["x"]), x0_before)
    np.testing.assert_array_equal(np.asarray(state2.m["x"]), m_before)
    assert float(metrics["survivors"]) == 0.0
    assert int(state2.t) == int(state.t) + 1  # the round still elapsed


def test_faulted_zero_sharded_matches_dense():
    """The weights threading through distributed/zero.py reproduces the
    dense masked mean on the (degenerate) mesh."""
    loss, batch_at, d = _quad_problem()
    from repro.launch.mesh import host_training_mesh

    plan = FaultPlan(4, 6, FaultSpec(p_drop=0.4, p_straggle=0.2,
                                     p_corrupt=0.3, seed=9))

    def run(zero_sharded):
        mesh = host_training_mesh(4) if zero_sharded else None
        cfg = DSMConfig(tau=2, global_lr=0.7, zero_sharded=zero_sharded)
        step = jax.jit(make_dsm_step(loss, sgd(), cfg, constant(0.05), mesh=mesh))
        state = dsm_init({"x": jnp.zeros((d,))}, sgd(), n_workers=4, mesh=mesh,
                         global_sharded=zero_sharded)
        for t in range(6):
            state, _ = step(state, batch_at(t), None, plan.round(t))
        return state

    dense, sharded = run(False), run(True)
    np.testing.assert_allclose(np.asarray(sharded.x0["x"]),
                               np.asarray(dense.x0["x"]), rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(sharded.m["x"]),
                               np.asarray(dense.m["x"]), rtol=0, atol=1e-6)


def test_faults_require_dsm_family():
    corpus = MarkovCorpus(NANO.vocab_size, branch=4, seed=7)
    with pytest.raises(ValueError, match="DSM step family"):
        run_training(NANO, nano_settings(algorithm="slowmo", steps=2,
                                         faults="drop=0.5"), corpus)


# ---------------------------------------------------------------------------
# chaos (a): 25% dropout still converges (final eval within 10% of clean)
# ---------------------------------------------------------------------------

def test_dropout_run_converges_near_fault_free():
    corpus = MarkovCorpus(NANO.vocab_size, branch=4, seed=7)
    clean = run_training(NANO, nano_settings(steps=16), corpus)
    faulty = run_training(
        NANO, nano_settings(steps=16, faults="drop=0.25,seed=5",
                            guard_nonfinite=True), corpus)
    assert np.isfinite(clean["final_eval"]) and np.isfinite(faulty["final_eval"])
    assert faulty["final_eval"] <= 1.10 * clean["final_eval"], (
        clean["final_eval"], faulty["final_eval"])
    # the guard never fired: dropout alone must not poison the state
    assert faulty["skipped_rounds"] == 0


# ---------------------------------------------------------------------------
# guards: skip-round semantics on spikes and non-finite updates
# ---------------------------------------------------------------------------

def _fake_step(state, batch, rng, faults=None):
    new_state = {"x": state["x"] + 1.0, "m": state["m"] + batch["poison"]}
    return new_state, {"loss": batch["loss"]}


def test_guard_skips_loss_spike_and_recovers():
    gstep = jax.jit(make_guarded_step(_fake_step, nonfinite=True,
                                      spike_factor=2.0, ema_beta=0.5))
    state, guard = {"x": jnp.zeros(3), "m": jnp.zeros(3)}, init_guard()
    losses, oks = [1.0, 1.1, 10.0, 1.0], []
    for loss in losses:
        batch = {"loss": jnp.float32(loss), "poison": jnp.float32(0.0)}
        state, guard, metrics = gstep(state, guard, batch, None, None)
        oks.append(bool(metrics["guard_ok"]))
    assert oks == [True, True, False, True]
    # the spiked round was skipped: only 3 accepted increments
    np.testing.assert_allclose(np.asarray(state["x"]), 3.0)
    assert int(guard.skipped) == 1 and int(guard.bad_streak) == 0
    assert int(guard.seen) == 3


def test_guard_skips_nonfinite_update_and_m_is_untouched():
    gstep = jax.jit(make_guarded_step(_fake_step, nonfinite=True))
    state, guard = {"x": jnp.zeros(3), "m": jnp.zeros(3)}, init_guard()
    batch = {"loss": jnp.float32(1.0), "poison": jnp.float32(jnp.nan)}
    new_state, guard, metrics = gstep(state, guard, batch, None, None)
    assert not bool(metrics["guard_ok"])
    np.testing.assert_array_equal(np.asarray(new_state["m"]),
                                  np.asarray(state["m"]))  # momentum untouched
    np.testing.assert_array_equal(np.asarray(new_state["x"]),
                                  np.asarray(state["x"]))
    assert int(guard.bad_streak) == 1


def test_guard_rollback_is_bounded():
    corpus = MarkovCorpus(NANO.vocab_size, branch=4, seed=7)
    logs = []
    with tempfile.TemporaryDirectory() as d:
        # spike_factor < 1: every round after the first is "bad" by
        # construction, so the run must roll back, retry, and then abort
        with pytest.raises(RuntimeError, match="training diverged"):
            run_training(NANO, nano_settings(
                n_workers=2, guard_spike_factor=0.5, guard_patience=2,
                guard_max_rollbacks=1, checkpoint_dir=d, checkpoint_every=2,
            ), corpus, log=logs.append)
    assert any("rollback #1" in line for line in logs)


# ---------------------------------------------------------------------------
# chaos (b): kill + resume is bit-exact (in-process: stop at k, resume)
# ---------------------------------------------------------------------------

def test_resume_reproduces_uninterrupted_run_bit_exactly():
    corpus = MarkovCorpus(NANO.vocab_size, branch=4, seed=7)
    ref = run_training(NANO, nano_settings(), corpus)
    with tempfile.TemporaryDirectory() as d:
        run_training(NANO, nano_settings(
            steps=4, checkpoint_dir=d, checkpoint_every=2), corpus)
        resumed = run_training(NANO, nano_settings(
            checkpoint_dir=d, checkpoint_every=2, resume=True), corpus)
    for a, b in zip(jax.tree.leaves(ref["state"].x0),
                    jax.tree.leaves(resumed["state"].x0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref["history"] == resumed["history"]
    assert ref["eval_losses"] == resumed["eval_losses"]


def test_resume_with_faults_replays_the_plan():
    """FaultPlan rounds are indexed by the outer step, so a resumed faulty
    run sees exactly the faults the uninterrupted run saw."""
    corpus = MarkovCorpus(NANO.vocab_size, branch=4, seed=7)
    kw = dict(faults="drop=0.25,nan=0.2,seed=4", guard_nonfinite=True)
    ref = run_training(NANO, nano_settings(**kw), corpus)
    with tempfile.TemporaryDirectory() as d:
        run_training(NANO, nano_settings(
            steps=4, checkpoint_dir=d, checkpoint_every=2, **kw), corpus)
        resumed = run_training(NANO, nano_settings(
            checkpoint_dir=d, checkpoint_every=2, resume=True, **kw), corpus)
    for a, b in zip(jax.tree.leaves(ref["state"].x0),
                    jax.tree.leaves(resumed["state"].x0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# comm accounting under dropout
# ---------------------------------------------------------------------------

def test_comm_accounting_under_dropout():
    from benchmarks.comm import bytes_per_outer_step

    full = bytes_per_outer_step("gpt2_small", "dsm", tau=12)
    faulty = bytes_per_outer_step("gpt2_small", "dsm", tau=12,
                                  survivor_frac=0.75)
    assert full["survivor_frac"] == 1.0
    assert full["expected_wire_bytes_per_outer"] == full["wire_bytes_per_outer"]
    # dropped workers source nothing: expected fabric traffic scales ...
    assert faulty["expected_wire_bytes_per_outer"] == int(
        round(0.75 * faulty["wire_bytes_per_outer"]))
    # ... but the survivors' round structure does not change
    assert faulty["comm_rounds_per_outer"] == full["comm_rounds_per_outer"]
    assert faulty["wire_bytes_per_outer"] == full["wire_bytes_per_outer"]
    with pytest.raises(ValueError, match="survivor_frac"):
        bytes_per_outer_step("gpt2_small", "dsm", tau=12, survivor_frac=1.5)


# ---------------------------------------------------------------------------
# the genuine kill: SIGKILL a training subprocess mid-run on the 8-device
# sharded + device-parallel + faulted stack, then --resume and compare
# ---------------------------------------------------------------------------

_KILL_SCRIPT = r"""
import os, signal, sys
import numpy as np
import jax
from repro.configs.base import ModelConfig
from repro.data.pipeline import MarkovCorpus
from repro.train.trainer import TrainSettings, run_training
from repro.checkpoint import checkpoint as CK

mode, ckdir, outdir = sys.argv[1], sys.argv[2], sys.argv[3]
NANO = ModelConfig(
    name="nano", family="lm", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=64, head_dim=16, mlp_gated=False, act="gelu",
    dtype="float32", param_dtype="float32", vocab_pad_to=64,
)
corpus = MarkovCorpus(64, seed=1)
kw = dict(algorithm="dsm", n_workers=4, tau=2, steps=6, b_micro=2, seq=32,
          eval_every=1, zero_sharded=True, device_parallel_local=True,
          faults="drop=0.25,nan=0.1,seed=5", guard_nonfinite=True)

if mode == "ref":
    s = TrainSettings(**kw)
elif mode == "victim":
    # checkpoint every round; SIGKILL ourselves at the 3rd log line — a
    # genuine mid-run kill with whatever checkpoints made it to disk
    s = TrainSettings(**kw, checkpoint_dir=ckdir, checkpoint_every=1)
    calls = []
    def killer(msg):
        calls.append(msg)
        if len(calls) == 3:
            os.kill(os.getpid(), signal.SIGKILL)
    run_training(NANO, s, corpus, log=killer)
    raise SystemExit("victim survived the kill")  # pragma: no cover
else:
    s = TrainSettings(**kw, checkpoint_dir=ckdir, checkpoint_every=1,
                      resume=True)

result = run_training(NANO, s, corpus)
x0 = {f"l{i}": np.asarray(l) for i, l in
      enumerate(jax.tree.leaves(result["state"].x0))}
np.savez(os.path.join(outdir, mode + "_x0.npz"), **x0)
print("DONE", mode, jax.device_count())
"""


@pytest.mark.multidevice
def test_kill_and_resume_bit_exact_8dev(tmp_path):
    """SIGKILL a sharded, fault-injected training run mid-flight; --resume
    must reproduce the uninterrupted run's final x0 bit-exactly."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    ckdir, outdir = str(tmp_path / "ck"), str(tmp_path)

    def run(mode, expect_rc=0):
        proc = subprocess.run(
            [sys.executable, "-c", _KILL_SCRIPT, mode, ckdir, outdir],
            capture_output=True, text=True, timeout=900, env=env,
        )
        if expect_rc is not None:
            assert proc.returncode == expect_rc, (mode, proc.stderr[-4000:])
        return proc

    run("ref")
    victim = run("victim", expect_rc=None)
    assert victim.returncode == -9, (victim.returncode, victim.stderr[-2000:])
    # the kill left a complete checkpoint behind but not the final state
    from repro.checkpoint.checkpoint import list_checkpoints

    steps = [s for s, _ in list_checkpoints(ckdir)]
    assert steps and max(steps) < 6, steps
    run("resume")

    ref = np.load(os.path.join(outdir, "ref_x0.npz"))
    res = np.load(os.path.join(outdir, "resume_x0.npz"))
    assert set(ref.files) == set(res.files)
    for k in ref.files:
        np.testing.assert_array_equal(ref[k], res[k])
