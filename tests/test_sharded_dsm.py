"""ZeRO-sharded global step (DSMConfig.zero_sharded) tests.

The multi-device equivalence test runs in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``: per the conftest
note, the main pytest process must keep seeing a single CPU device, and XLA
device count is fixed at first jax use.  Everything else runs in-process on
the 1-device degenerate mesh (worker=1, zero=1), which exercises the same
code path cheaply.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DSMConfig, constant, dsm_init, make_dsm_step, sgd
from repro.distributed import zero as Z
from repro.launch.mesh import host_training_mesh

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


# ---------------------------------------------------------------------------
# slab plumbing
# ---------------------------------------------------------------------------

def test_slab_roundtrip():
    key = jax.random.PRNGKey(0)
    for shape in ((5,), (33, 40), (2, 64, 16), ()):
        x = jax.random.normal(key, shape)
        slab = Z._to_slab(x, 8)
        assert slab.shape[1] == 128 and slab.shape[0] % 8 == 0
        np.testing.assert_array_equal(np.asarray(Z._from_slab(slab, x)),
                                      np.asarray(x))


def test_global_buffer_pspecs_use_worker_and_zero():
    from repro.distributed.sharding import param_pspecs

    tree = {"w": jnp.zeros((16, 8)), "tiny": jnp.zeros((3,))}
    specs = param_pspecs(tree, model=1, zero=8, zero_axes=Z.GLOBAL_AXES)
    # divisible leaf: largest divisible dim carries the flattened axes
    assert tuple(specs["w"]) == (Z.GLOBAL_AXES, None)
    # indivisible leaf stays replicated
    assert all(s is None for s in tuple(specs["tiny"]))


# ---------------------------------------------------------------------------
# 1-device degenerate mesh: zero_sharded wiring == replicated, in-process
# ---------------------------------------------------------------------------

def _quad_setup(use_kernel, zero_sharded, steps=3):
    d = 48
    key = jax.random.PRNGKey(7)
    center = jax.random.normal(key, (d,))

    def loss(params, batch):
        tgt = center + batch["noise"]
        return 0.5 * jnp.mean(jnp.sum((params["x"][None] - tgt) ** 2, axis=-1))

    mesh = host_training_mesh(2) if zero_sharded else None
    cfg = DSMConfig(tau=2, global_lr=0.7, use_kernel=use_kernel,
                    zero_sharded=zero_sharded)
    step = jax.jit(make_dsm_step(loss, sgd(), cfg, constant(0.05), mesh=mesh))
    state = dsm_init({"x": jnp.zeros((d,))}, sgd(), n_workers=2, mesh=mesh)
    for t in range(steps):
        batch = {"noise": 0.1 * jax.random.normal(
            jax.random.fold_in(key, t), (2, 2, 1, 4, d))}
        state, _ = step(state, batch)
    return state


@pytest.mark.parametrize("use_kernel", [False, True])
def test_zero_sharded_single_device_matches(use_kernel):
    ref = _quad_setup(use_kernel, zero_sharded=False)
    sh = _quad_setup(use_kernel, zero_sharded=True)
    np.testing.assert_allclose(np.asarray(sh.x0["x"]), np.asarray(ref.x0["x"]),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(sh.m["x"]), np.asarray(ref.m["x"]),
                               rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# 8-device equivalence: sharded vs replicated training, jnp AND kernel paths
# ---------------------------------------------------------------------------

_EQUIV_SCRIPT = r"""
import json
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import DSMConfig, constant, dsm_init, make_dsm_step, get_base_optimizer
from repro.data.pipeline import MarkovCorpus, dsm_batches
from repro.launch.mesh import host_training_mesh
from repro.models import transformer as T

NANO = ModelConfig(
    name="nano", family="lm", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=64, head_dim=16, mlp_gated=False, act="gelu",
    dtype="float32", param_dtype="float32", vocab_pad_to=64,
)
W, TAU, STEPS = 4, 2, 5
loss = lambda p, mb: T.loss_fn(p, mb, NANO, remat=False)
base = get_base_optimizer("adamw")


def run(zero_sharded, use_kernel):
    mesh = host_training_mesh(W) if zero_sharded else None
    cfg = DSMConfig(tau=TAU, global_lr=1.0, zero_sharded=zero_sharded,
                    use_kernel=use_kernel)
    step = jax.jit(make_dsm_step(loss, base, cfg, constant(2e-2), mesh=mesh))
    params = T.init_params(jax.random.PRNGKey(3), NANO)
    state = dsm_init(params, base, W, mesh=mesh)
    batches = dsm_batches(MarkovCorpus(64, seed=1), W, TAU, 1, 2, 32, seed=3)
    for _ in range(STEPS):
        state, _ = step(state, jax.tree.map(jnp.asarray, next(batches)))
    return state


def maxdiff(a, b):
    return max(float(jnp.abs(x - y).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


rec = {"n_devices": jax.device_count()}
for name, use_kernel in (("jnp", False), ("kernel", True)):
    ref = run(False, use_kernel)
    sh = run(True, use_kernel)
    # every large leaf of the sharded x0 / m must really live in 1/8 shards
    shard_frac = []
    for leaf in jax.tree.leaves(sh.x0) + jax.tree.leaves(sh.m):
        ss = leaf.sharding.shard_shape(leaf.shape)
        shard_frac.append(
            int(jnp.prod(jnp.array(ss))) / max(leaf.size, 1))
    rec[name] = {
        "x0": maxdiff(ref.x0, sh.x0),
        "m": maxdiff(ref.m, sh.m),
        "min_shard_frac": min(shard_frac),
    }
print("RESULT " + json.dumps(rec))
"""


@pytest.mark.multidevice
def test_sharded_training_matches_replicated_8dev():
    """zero_sharded=True == replicated to 1e-5 after 5 outer steps, on a
    forced 8-device host (worker=4, zero=2), jnp and fused-kernel paths."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _EQUIV_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rec = json.loads(line[len("RESULT "):])
    assert rec["n_devices"] == 8
    for path in ("jnp", "kernel"):
        assert rec[path]["x0"] <= 1e-5, (path, rec)
        assert rec[path]["m"] <= 1e-5, (path, rec)
        # the big buffers are genuinely 8-way sharded (small leaves replicate)
        assert rec[path]["min_shard_frac"] <= 1 / 8 + 1e-9, (path, rec)


# ---------------------------------------------------------------------------
# comm model: the sharding's accounting
# ---------------------------------------------------------------------------

def test_comm_model_reports_sharded_reduction():
    from benchmarks.comm import bytes_per_outer_step

    rep = bytes_per_outer_step("gpt2_small", "dsm", tau=12)
    sh = bytes_per_outer_step("gpt2_small", "dsm", tau=12,
                              zero_sharded=True, shards=8)
    assert rep["global_state_shards"] == 1 and sh["global_state_shards"] == 8
    for key in ("global_buffer_bytes_per_rank", "global_state_bytes_per_rank",
                "broadcast_src_bytes_per_rank"):
        ratio = sh[key] / rep[key]
        assert abs(ratio - 1 / 8) < 1e-6, (key, ratio)
    # wire volume is tau-amortized either way; sharding must not change it
    assert sh["wire_bytes_per_outer"] == rep["wire_bytes_per_outer"]
