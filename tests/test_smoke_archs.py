"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
variant of each assigned arch family, run one forward + one DSM outer
train step + one decode step on CPU; assert output shapes and finiteness.

FULL configs are exercised only via the dry-run (no allocation here).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, PAPER_ARCH_IDS, load_arch
from repro.core import DSMConfig, constant, dsm_init, get_base_optimizer, make_dsm_step
from repro.models import transformer as T

ALL_IDS = ARCH_IDS + PAPER_ARCH_IDS

# archs whose smoke step takes >~8s on CPU (recurrent scans, MoE routing,
# audio/VLM encoders): tier-2.  The cheap decoder-only ones stay in tier-1
# so every commit still exercises the full forward+DSM+decode path.
_SLOW_ARCHS = {
    "recurrentgemma_2b", "llama4_maverick_400b_a17b", "mamba2_780m",
    "whisper_large_v3", "minitron_4b", "deepseek_67b", "llava_next_34b",
    "granite_moe_3b_a800m", "gpt2_large", "gemma3_1b",
}
_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS
    else pytest.param(a)
    for a in ALL_IDS
]


def _smoke_batch(cfg, key, W=2, tau=2, accum=1, B=2, S=32):
    lead = (W, tau, accum, B)
    batch = {}
    if cfg.family == "vlm":
        batch["tokens"] = jax.random.randint(key, lead + (S - cfg.n_patches,), 0, cfg.vocab_size)
        batch["patches"] = jax.random.normal(key, lead + (cfg.n_patches, cfg.d_model), jnp.float32)
    elif cfg.family == "encdec":
        batch["tokens"] = jax.random.randint(key, lead + (S,), 0, cfg.vocab_size)
        batch["frames"] = jax.random.normal(key, lead + (cfg.enc_len, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, lead + (S,), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch_id", _PARAMS)
def test_smoke_forward_and_train_step(arch_id):
    mod = load_arch(arch_id)
    cfg, topo = mod.SMOKE, mod.TOPO
    assert cfg.n_layers <= 2 and cfg.d_model <= 512 and cfg.n_experts <= 4

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)

    # forward / loss
    batch = _smoke_batch(cfg, key)
    micro = jax.tree.map(lambda x: x[0, 0, 0], batch)
    loss = T.loss_fn(params, micro, cfg, remat=False)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch_id

    # one DSM outer step with the arch's configured base optimizer
    base = get_base_optimizer(topo.base_opt)
    step = make_dsm_step(
        lambda p, b: T.loss_fn(p, b, cfg, remat=False),
        base, DSMConfig(tau=2, global_lr=0.5), constant(1e-3),
    )
    state = dsm_init(params, base, n_workers=2)
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch_id
    for leaf in jax.tree.leaves(state.x0):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch_id

    # params actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(state.x0), jax.tree.leaves(params))
    )
    assert moved, arch_id


@pytest.mark.parametrize("arch_id", ALL_IDS)
def test_smoke_decode_step(arch_id):
    mod = load_arch(arch_id)
    cfg = mod.SMOKE
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    B, S_max = 2, 48
    cache = T.init_cache(cfg, B, S_max, jnp.float32)
    if cfg.family == "encdec":
        # fill cross-attn cache entries with encoder output shapes
        pass  # init_cache already allocates kx/vx at enc_len
    tok = jax.random.randint(key, (B,), 0, cfg.vocab_size)
    logits, new_cache = jax.jit(
        lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg)
    )(params, cache, tok, jnp.int32(3))
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch_id


@pytest.mark.parametrize("arch_id", ALL_IDS)
def test_full_config_abstract_shapes(arch_id):
    """FULL configs must eval_shape cleanly (no allocation)."""
    from repro.configs import specs as S

    mod = load_arch(arch_id)
    n = S.param_count(mod.FULL)
    assert n > 0
    aps = S.abstract_params(mod.FULL)
    assert all(l.shape is not None for l in jax.tree.leaves(aps))
