"""Substrate tests: data pipeline, checkpointing, serving, sharding rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as CK
from repro.configs import ARCH_IDS, load_arch
from repro.configs import specs as S
from repro.configs.base import ModelConfig
from repro.data.pipeline import MarkovCorpus, TextCorpus, dsm_batches
from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.train.serve import generate


def test_markov_corpus_shapes_and_determinism():
    c = MarkovCorpus(100, seed=3)
    r1 = c.sample(np.random.default_rng(0), 4, 32)
    r2 = c.sample(np.random.default_rng(0), 4, 32)
    np.testing.assert_array_equal(r1, r2)
    assert r1.shape == (4, 32) and r1.dtype == np.int32
    assert r1.min() >= 0 and r1.max() < 100


def test_markov_corpus_is_learnable_structure():
    """An order-2 table must make the chain's bigram-conditional entropy
    far below uniform — i.e. there's signal for training curves."""
    c = MarkovCorpus(50, branch=4, seed=0)
    seq = c.sample(np.random.default_rng(1), 1, 5000)[0]
    # empirical conditional entropy given (t-2,t-1) — estimate on pairs
    from collections import Counter, defaultdict

    ctx = defaultdict(Counter)
    for i in range(2, len(seq)):
        ctx[(seq[i - 2], seq[i - 1])][seq[i]] += 1
    ents = []
    for counter in ctx.values():
        tot = sum(counter.values())
        if tot < 5:
            continue
        p = np.array([v / tot for v in counter.values()])
        ents.append(-(p * np.log(p)).sum())
    assert np.mean(ents) < np.log(50) * 0.75


def test_dsm_batches_layout_and_heterogeneity():
    c = MarkovCorpus(64, seed=0)
    it = dsm_batches(c, n_workers=3, tau=2, accum=2, b_micro=4, seq=16, seed=5)
    b = next(it)
    assert b["tokens"].shape == (3, 2, 2, 4, 16)
    # heterogeneous: workers draw from distinct streams
    assert not np.array_equal(b["tokens"][0], b["tokens"][1])


def test_text_corpus_self_hosting():
    c = TextCorpus(root=os.path.join(os.path.dirname(__file__), ".."),
                   pattern="src/**/*.py")
    s = c.sample(np.random.default_rng(0), 2, 64)
    assert s.shape == (2, 64) and s.max() < 256


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16) * 1.5,
                   "c": jnp.arange(3, dtype=jnp.int32)},
    }
    path = str(tmp_path / "ck")
    CK.save(path, tree, step=42)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, step = CK.restore(path, like)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_generate_matches_forward_oracle():
    cfg = ModelConfig(
        name="t", family="lm", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=300, head_dim=16,
        pattern=("swa:dense", "attn:dense"), window=8,
        dtype="float32", param_dtype="float32",
    )
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    prompt = jax.random.randint(key, (2, 21), 0, 300)
    toks, stats = generate(params, cfg, prompt, max_new_tokens=4)
    cur = prompt
    for i in range(4):
        h, _, _ = T.hidden_states(params, {"tokens": cur}, cfg, remat=False)
        lg = T._logits(params, h, cfg)[:, -1, : cfg.vocab_size]
        nxt = jnp.argmax(lg, -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(nxt), np.asarray(toks[:, i]))
        cur = jnp.concatenate([cur, nxt[:, None]], axis=1)
    assert stats["tok_per_s"] > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_sharding_rules_divisible(arch_id):
    """Every sharded dim must divide by its mesh-axis product (16x16 mesh)."""
    from jax.sharding import PartitionSpec as P

    mod = load_arch(arch_id)
    aps = S.abstract_params(mod.FULL)
    W = mod.TOPO.n_workers_single
    zero = max(16 // W, 1)
    wparams = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((W,) + l.shape, l.dtype), aps)
    specs = shd.param_pspecs(wparams, model=16, zero=zero, worker_axis=True)
    sizes = {"worker": W, "zero": zero, "model": 16}

    flat_l = jax.tree_util.tree_flatten_with_path(wparams)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_l) == len(flat_s)
    for (path, leaf), spec in zip(flat_l, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            prod = 1
            for a in axes:
                prod *= sizes[a]
            assert dim % prod == 0, (arch_id, path, leaf.shape, spec)
