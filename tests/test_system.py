"""End-to-end behaviour tests: the paper's qualitative claims at CPU scale.

Every test here trains for real (minutes each on CPU), so the whole module
is tier-2: marked slow, deselected by the default -m "not slow" invocation,
run by the scheduled CI job.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs.base import ModelConfig
from repro.data.pipeline import MarkovCorpus
from repro.train.trainer import TrainSettings, run_training

NANO = ModelConfig(
    name="nano", family="lm", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=64, head_dim=16, mlp_gated=False, act="gelu",
    dtype="float32", param_dtype="float32", vocab_pad_to=64,
)


@pytest.fixture(scope="module")
def corpus():
    return MarkovCorpus(NANO.vocab_size, branch=4, seed=7)


def _run(algo, corpus, steps=24, **kw):
    defaults = dict(
        algorithm=algo, n_workers=4, tau=4, steps=steps, b_micro=8, seq=128,
        peak_lr=1e-2, warmup=5, eval_every=steps,
    )
    defaults.update(kw)
    return run_training(NANO, TrainSettings(**defaults), corpus)


def test_training_reduces_loss(corpus):
    r = _run("dsm", corpus, global_lr=1.0, dsm_beta1=0.9, dsm_beta2=0.95)
    assert r["history"][-1] < r["history"][0] - 0.1
    assert np.isfinite(r["final_eval"])


def test_all_algorithms_run_and_learn(corpus):
    # signed_slowmo steps ~ eta*(1-beta) per coordinate per outer step
    # (sign inside the momentum, paper S4.1) -> needs a much smaller eta
    lrs = {"signed_slowmo": 0.005, "signed_lookahead": 0.3, "mv_signsgd": 0.3}
    for algo in ("slowmo", "signed_slowmo", "lookahead", "signed_lookahead",
                 "global_adamw", "local_avg", "perstep", "mv_signsgd"):
        r = _run(algo, corpus, steps=8, global_lr=lrs.get(algo, 1.0))
        assert np.isfinite(r["final_eval"]), algo
        # 8 outer steps: require stability (no divergence); learning-rate
        # quality is asserted per-algorithm in the dedicated tests above.
        assert r["history"][-1] < r["history"][0] + 0.2, algo


def test_dsm_beats_slowmo_in_noisy_regime(corpus):
    """Theory (Remark 2): DSM is preferable in the LARGE-NOISE regime.
    With batch=1, seq=32 local gradients, sign momentum beats SlowMo at the
    same communication budget.  (In the clean small-scale regime SlowMo
    wins — the paper's advantage is transformer-scale/long-horizon; see
    EXPERIMENTS.md for the full account.)"""
    kw = dict(b_micro=1, seq=32, tau=8, steps=100)
    r_dsm = _run("dsm", corpus, global_lr=1.0,
                 dsm_beta1=0.9, dsm_beta2=0.95, **kw)
    r_sm = _run("slowmo", corpus, slow_beta=0.5, **kw)
    assert r_dsm["final_eval"] < r_sm["final_eval"] + 0.02


def test_comm_accounting(corpus):
    r_dsm = _run("dsm", corpus, steps=6, global_lr=0.3)
    r_ps = _run("perstep", corpus, steps=6)
    assert r_ps["comm_rounds"] == r_dsm["comm_rounds"] * 4  # tau = 4
    assert r_ps["tokens"] == r_dsm["tokens"]               # same compute


def test_kernel_training_path_matches_jnp(corpus):
    """DSM trained with the fused Pallas kernel == jnp path, same seeds."""
    r1 = _run("dsm", corpus, steps=4, global_lr=0.3, use_kernel=False)
    r2 = _run("dsm", corpus, steps=4, global_lr=0.3, use_kernel=True)
    np.testing.assert_allclose(r1["history"], r2["history"], rtol=1e-4)
    np.testing.assert_allclose(r1["final_eval"], r2["final_eval"], rtol=1e-4)


def test_randomized_sign_training_runs(corpus):
    """The theory's randomized-sign variant (Thm 1/2) trains stably."""
    r = _run("dsm", corpus, steps=8, global_lr=0.3, sign_mode="rand_pm")
    assert np.isfinite(r["final_eval"])
    assert r["history"][-1] < r["history"][0]
